"""Delta-aware checkpoint pipeline: record-side fingerprint/transfer flow,
delta-manifest round-trips, full-manifest cadence, GC, crash-safety."""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointPipeline, CheckpointStore


@pytest.fixture()
def store(tmp_path):
    return CheckpointStore(str(tmp_path / "store"))


def _tree(step: float):
    """Frozen-majority state: one big frozen leaf, one small hot head."""
    frozen = jax.random.normal(jax.random.PRNGKey(0), (64 * 256,))
    head = jnp.full((256,), step, jnp.float32)
    return {"frozen": frozen, "head": head,
            "opt": {"mu": jnp.full((256,), step / 2, jnp.float32)}}


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if str(x.dtype) != str(y.dtype) or not np.array_equal(x, y):
            return False
    return True


def test_delta_roundtrip_frozen_subtree_bit_identical(store):
    """Record with a frozen majority; every checkpoint (full or delta)
    restores bit-identically, and delta checkpoints transfer only the hot
    fraction."""
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=4,
                              async_stage=False)
    trees = {}
    for i in range(9):
        trees[i] = _tree(float(i + 1))
        s = pipe.submit(f"ck{i}", trees[i], scope="train")
        if s["kind"] == "delta":
            # only head+opt chunks moved: 2 chunks of 1024B out of 66
            assert s["transferred_bytes"] <= 3 * 256 * 4
            assert s["transferred_bytes"] < 0.05 * s["logical_bytes"]
    pipe.close()
    for i in range(9):
        back = store.get_tree(f"ck{i}", like=trees[i])
        assert _leaves_equal(trees[i], back)
        # restored arrays must be writable (np.frombuffer views are not)
        for leaf in jax.tree_util.tree_leaves(back):
            assert np.asarray(leaf).flags.writeable


def test_full_manifest_cadence_bounds_chains(store):
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=3,
                              async_stage=False)
    for i in range(10):
        pipe.submit(f"ck{i}", _tree(float(i)), scope="train")
    pipe.close()
    kinds = [store.get_manifest(f"ck{i}")["kind"] for i in range(10)]
    assert kinds == ["full", "delta", "delta"] * 3 + ["full"]
    # resolve depth never exceeds full_every - 1
    for i in range(10):
        m = store.get_manifest(f"ck{i}")
        depth = 0
        while m.get("parent"):
            m = store.get_manifest(m["parent"])
            depth += 1
        assert depth <= 2


def test_structure_change_forces_full_manifest(store):
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=100,
                              async_stage=False)
    t = _tree(1.0)
    pipe.submit("a", t, scope="s")
    s = pipe.submit("b", dict(t, head=t["head"] + 1), scope="s")
    assert s["kind"] == "delta"
    # dtype change: same bytes-per-chunk topology must NOT alias stale data
    t2 = dict(t, head=(t["head"] + 1).astype(jnp.int32))
    s = pipe.submit("c", t2, scope="s")
    assert s["kind"] == "full"
    # new leaf
    s = pipe.submit("d", dict(t2, extra=jnp.ones((10,))), scope="s")
    assert s["kind"] == "full"
    # leaf removed
    s = pipe.submit("e", t2, scope="s")
    assert s["kind"] == "full"
    pipe.close()
    back = store.get_tree("c", like=t2)
    assert _leaves_equal(t2, back)


def test_delta_restore_matches_full_transfer_restore(store):
    """The acceptance check: a delta-restored tree is bit-identical to a
    full-manifest (put_tree) restore of the same state."""
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=8,
                              async_stage=False)
    t = None
    for i in range(5):
        t = _tree(float(i) * 0.5)
        pipe.submit(f"ck{i}", t, scope="train")
    pipe.close()
    assert store.get_manifest("ck4")["kind"] == "delta"
    store.put_tree("full_ck4", t)                  # classic whole-tree path
    via_delta = store.get_tree("ck4", like=t)
    via_full = store.get_tree("full_ck4", like=t)
    assert _leaves_equal(via_delta, via_full)


def test_mixed_dtypes_roundtrip(store):
    pipe = CheckpointPipeline(store, chunk_words=256, async_stage=False)
    tree = {
        "f32": jax.random.normal(jax.random.PRNGKey(0), (33, 7)),
        "bf16": jax.random.normal(jax.random.PRNGKey(1),
                                  (301,)).astype(jnp.bfloat16),
        "f16": jax.random.normal(jax.random.PRNGKey(2),
                                 (257,)).astype(jnp.float16),
        "i64": jnp.arange(11, dtype=jnp.int64),
        "u8": jnp.asarray(list(range(97)), jnp.uint8),
        "scalar": jnp.asarray(3.5),
        "step": jnp.asarray(7, jnp.int32),
    }
    pipe.submit("a", tree, scope="s")
    bumped = dict(tree, scalar=jnp.asarray(4.5),
                  step=jnp.asarray(8, jnp.int32))
    s = pipe.submit("b", bumped, scope="s")
    pipe.close()
    assert s["kind"] == "delta"
    assert _leaves_equal(bumped, store.get_tree("b", like=bumped))
    assert _leaves_equal(tree, store.get_tree("a", like=tree))


def test_unchanged_resubmission_transfers_nothing(store):
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=100,
                              async_stage=False)
    t = _tree(1.0)
    pipe.submit("a", t, scope="s")
    s = pipe.submit("b", t, scope="s")
    pipe.close()
    assert s["transferred_bytes"] == 0 and s["changed_chunks"] == 0
    assert _leaves_equal(t, store.get_tree("b", like=t))


def test_scopes_are_isolated(store):
    """Interleaved blocks must not diff against each other's trees."""
    pipe = CheckpointPipeline(store, chunk_words=256, async_stage=False)
    ta, tb = _tree(1.0), _tree(100.0)
    pipe.submit("a0", ta, scope="A")
    pipe.submit("b0", tb, scope="B")
    sa = pipe.submit("a1", dict(ta, head=ta["head"] + 1), scope="A")
    sb = pipe.submit("b1", dict(tb, head=tb["head"] + 1), scope="B")
    pipe.close()
    assert sa["kind"] == "delta" and sa["parent"] == "a0"
    assert sb["kind"] == "delta" and sb["parent"] == "b0"
    assert _leaves_equal(dict(ta, head=ta["head"] + 1),
                         store.get_tree("a1", like=ta))
    assert _leaves_equal(dict(tb, head=tb["head"] + 1),
                         store.get_tree("b1", like=tb))


def test_async_pipeline_matches_sync(tmp_path):
    s_async = CheckpointStore(str(tmp_path / "a"))
    s_sync = CheckpointStore(str(tmp_path / "b"))
    pa = CheckpointPipeline(s_async, chunk_words=256, full_every=3)
    ps = CheckpointPipeline(s_sync, chunk_words=256, full_every=3,
                            async_stage=False)
    trees = {i: _tree(float(i)) for i in range(7)}
    for i, t in trees.items():
        pa.submit(f"ck{i}", t, scope="train")
        ps.submit(f"ck{i}", t, scope="train")
    pa.close()
    ps.close()
    assert len(pa.stats) == len(ps.stats) == 7
    for i, t in trees.items():
        assert _leaves_equal(s_async.get_tree(f"ck{i}", like=t),
                             s_sync.get_tree(f"ck{i}", like=t))


def test_gc_keeps_all_live_chunks(store):
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=3,
                              async_stage=False)
    trees = {}
    for i in range(9):
        trees[i] = _tree(float(i))
        pipe.submit(f"ck{i}", trees[i], scope="train")
    pipe.close()
    # retention: keep only the delta ck7 — gc must keep its parent chain
    stats = store.gc(["ck7"])
    assert stats["deleted_manifests"] > 0
    assert store.has("ck7") and store.has("ck6")   # parent closure retained
    back = store.get_tree("ck7", like=trees[7])
    la = jax.tree_util.tree_leaves(trees[7])
    lb = jax.tree_util.tree_leaves(back)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # a second pass with everything live is a no-op
    stats2 = store.gc(store.list_keys())
    assert stats2["deleted_chunks"] == 0 and stats2["deleted_manifests"] == 0


def test_gc_with_real_checkpoint_keys(store):
    """Live keys arrive RAW ('train@2.0') while manifests are stored under
    sanitized names — gc must not treat every real key as dead."""
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=3,
                              async_stage=False)
    trees = {}
    for e in range(5):
        trees[e] = _tree(float(e))
        pipe.submit(f"train@{e}.0", trees[e], scope="train")
    pipe.close()
    stats = store.gc(["train@4.0"])
    assert store.has("train@4.0") and store.has("train@3.0")  # parent chain
    assert stats["deleted_manifests"] == 3
    back = store.get_tree("train@4.0", like=trees[4])
    for x, y in zip(jax.tree_util.tree_leaves(trees[4]),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_python_scalar_leaves_roundtrip(store):
    """State trees may carry plain Python scalars (step counters etc.) —
    the pipeline must checkpoint them like put_tree always did."""
    pipe = CheckpointPipeline(store, chunk_words=256, async_stage=False)
    t = {"w": jnp.ones((1024,)), "step": 3, "lr": 1e-3, "done": False}
    pipe.submit("a", t, scope="s")
    s = pipe.submit("b", dict(t, step=4), scope="s")
    pipe.close()
    assert s["kind"] == "delta"
    back = store.get_tree("b", like=t)
    assert int(back["step"]) == 4
    assert float(back["lr"]) == 1e-3
    assert not bool(back["done"])


def test_gc_collects_orphans(store):
    t = {"x": jnp.arange(4096, dtype=jnp.float32)}
    store.put_tree("keep", t)
    store.put_tree("drop", {"y": jnp.ones((8192,), jnp.float32)})
    before = store.stored_bytes()
    stats = store.gc(["keep"])
    assert stats["deleted_chunks"] >= 1
    assert store.stored_bytes() < before
    assert not store.has("drop")
    back = store.get_tree("keep", like=t)
    np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(t["x"]))


def test_crash_safety_tmp_files_ignored_and_collected(store):
    """Stray .tmp files from a crashed writer are never read as data and are
    not confused with live chunks by gc."""
    t = {"x": jnp.arange(100.0)}
    pipe = CheckpointPipeline(store, chunk_words=256, async_stage=False)
    pipe.submit("good", t, scope="s")
    pipe.close()
    obj_dir = os.path.join(store.root, "objects", "zz")
    os.makedirs(obj_dir, exist_ok=True)
    with open(os.path.join(obj_dir, "deadbeef.zst.tmp.99.1"), "wb") as f:
        f.write(b"garbage")
    with open(os.path.join(store.root, "manifests",
                           "half.msgpack.tmp.99.1"), "wb") as f:
        f.write(b"garbage")
    assert not store.has("half")
    assert "half" not in store.list_keys()
    back = store.get_tree("good", like=t)
    np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(t["x"]))
    store.gc(["good"])          # must not crash on the stray tmp files
    back = store.get_tree("good", like=t)
    np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(t["x"]))


def test_manifest_write_is_atomic_replace(store):
    """put_manifest goes through tmp+os.replace: after the write there is
    exactly one manifest file and no leftover tmp."""
    pipe = CheckpointPipeline(store, chunk_words=256, async_stage=False)
    pipe.submit("k", {"x": jnp.ones((2048,))}, scope="s")
    pipe.close()
    mdir = os.path.join(store.root, "manifests")
    assert sorted(os.listdir(mdir)) == ["k.msgpack"]
    assert not glob.glob(os.path.join(mdir, "*.tmp.*"))


def test_rolling_retention_gc_mid_record(tmp_path):
    """ctx.gc(keep_keys=...) DURING record must keep the active delta-chain
    tip live — otherwise every later checkpoint inherits chunk hashes from
    deleted manifests and is unrestorable."""
    from repro.core.context import FlorContext
    ctx = FlorContext(str(tmp_path / "run"), "record", adaptive=False,
                      async_materialize=False, full_manifest_every=100)
    t = _tree(1.0)
    for e in range(6):
        t = dict(t, head=t["head"] + 1)
        ctx.submit_checkpoint("train", f"train@{e}.0", t, meta={})
    # retention asks to keep only epoch 1; the chain tip train@5.0 (and its
    # parent closure) must survive anyway
    ctx.gc(keep_keys=["train@1.0"])
    assert ctx.store.has("train@1.0") and ctx.store.has("train@5.0")
    # the next delta checkpoint still restores bit-identically
    t = dict(t, head=t["head"] + 1)
    ctx.submit_checkpoint("train", "train@6.0", t, meta={})
    back = ctx.store.get_tree("train@6.0", like=t)
    for x, y in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    ctx.finish()


def test_gather_width_bucketing_changes_roundtrip(store):
    """Fluctuating changed-chunk counts (gather width bucketing pads to
    powers of two) must not corrupt what gets stored."""
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=100,
                              async_stage=False)
    x = jax.random.normal(jax.random.PRNGKey(0), (64 * 256,))
    pipe.submit("ck0", {"x": x}, scope="s")
    rng = np.random.default_rng(0)
    for step, nchanged in enumerate([1, 3, 7, 2, 5, 64]):
        x = np.asarray(x).copy()
        rows = rng.choice(64, size=nchanged, replace=False)
        for r in rows:
            x[r * 256] += 1.0
        x = jnp.asarray(x)
        s = pipe.submit(f"ck{step + 1}", {"x": x}, scope="s")
        assert s["changed_chunks"] == nchanged
        back = store.get_tree(f"ck{step + 1}", like={"x": x})
        np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(x))
    pipe.close()


def test_calibration_leaves_no_artifacts(tmp_path):
    """The adaptive controller's store-throughput probe must not pollute
    list_keys() or stored_bytes() accounting."""
    import repro.flor as flor
    run = str(tmp_path / "run")
    flor.init(run, mode="record", adaptive=True)
    ctx = flor.get_context()
    assert ctx.controller.write_bps >= 1e7         # calibration happened
    assert "__calib__" not in ctx.store.list_keys()
    assert ctx.store.stored_bytes() == 0
    flor.finish()


def test_queue_full_rolls_back_digests(tmp_path):
    """A skipped (queue-full, block=False) checkpoint must not advance the
    device digest state: the next delta still diffs against the last STORED
    checkpoint."""
    store = CheckpointStore(str(tmp_path / "s"))
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=100,
                              async_stage=False)
    t = _tree(1.0)
    pipe.submit("a", t, scope="s")
    # simulate a full queue by swapping in a writer stub that rejects
    class _Full:
        def submit_job(self, key, fn, block=True):
            return False
    real_writer = pipe.writer
    pipe.writer = _Full()
    skipped = pipe.submit("b", dict(t, head=t["head"] + 1), scope="s")
    assert skipped is None
    pipe.writer = real_writer
    s = pipe.submit("c", dict(t, head=t["head"] + 2), scope="s")
    pipe.close()
    # head changed relative to "a" — must be transferred even though the
    # intermediate submit saw (and dropped) a newer digest
    assert s["changed_chunks"] >= 1
    back = store.get_tree("c", like=t)
    np.testing.assert_array_equal(np.asarray(back["head"]),
                                  np.asarray(t["head"]) + 2)
