"""Adaptive wire encodings: the int4 gather-quantize kernel and wire codec,
the writer-thread entropy stage, the per-chunk error-bound selector
(RecordSpec.ckpt_error_bounds), and the auto-retuned full-manifest cadence.
All in-process on the default 1-device CPU."""
import os
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointPipeline, CheckpointStore
from repro.checkpoint.delta import DeltaTracker, Q4_ATOL_DIV, Q8_ATOL_DIV
from repro.kernels.ops import (chunk_absmax, decode_wire_chunk,
                               gather_quantize4_blocks, q4_decode_chunk,
                               q4_encode_chunk, q8_encode_chunk)
from repro.kernels.quantize import Q4_BLOCK, gather_quantize4_pallas
from repro.kernels.ref import gather_quantize4_ref
from repro.parallel.compression import (entropy_decode_bytes,
                                        entropy_encode_bytes)


# ------------------------------------------------------------ q4 kernel --
def test_q4_pallas_matches_ref():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 512)).astype(np.float32))
    idx = jnp.asarray([1, 4, 7], jnp.int32)
    p_k, s_k = gather_quantize4_pallas(x, idx, block=256, interpret=True)
    p_r, s_r = gather_quantize4_ref(x, idx, 256)
    # interpret-mode lowering may round scales differently by 1 ulp, which
    # can flip a borderline nibble; the packings must agree to one level
    assert np.allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)
    lo_k, hi_k = np.asarray(p_k) & 0xF, np.asarray(p_k) >> 4
    lo_r, hi_r = np.asarray(p_r) & 0xF, np.asarray(p_r) >> 4
    for a, b in ((lo_k, lo_r), (hi_k, hi_r)):
        d = (a.astype(np.int8) - ((a > 7) << 4)) \
            - (b.astype(np.int8) - ((b > 7) << 4))
        assert np.max(np.abs(d)) <= 1
    assert p_k.shape == (3, 256) and p_k.dtype == jnp.uint8
    assert s_k.shape == (3, 2) and s_k.dtype == jnp.float32


def test_q4_roundtrip_error_bounded():
    rng = np.random.default_rng(1)
    chunk_words = 512
    x = rng.normal(size=(4 * chunk_words,)).astype(np.float32)
    idx = jnp.asarray([0, 2, 3], jnp.int32)
    p, s = gather_quantize4_blocks(jnp.asarray(x), idx, chunk_words)
    rows = x.reshape(4, chunk_words)
    amax = np.abs(rows).reshape(4, -1, Q4_BLOCK).max(axis=2)
    for j, c in enumerate(np.asarray(idx)):
        wire = q4_encode_chunk(np.asarray(p)[j], np.asarray(s)[j],
                               chunk_words, Q4_BLOCK)
        got = np.frombuffer(q4_decode_chunk(wire, "float32"), np.float32)
        err = np.abs(got - rows[c]).reshape(-1, Q4_BLOCK).max(axis=1)
        # guaranteed half-step bound per 256-element block
        assert np.all(err <= amax[c] / 14.0 + 1e-9)


def test_q4_partial_chunk_trims_on_decode():
    # last chunk of a leaf is partial: header n_elems trims after unpack
    chunk_words = 256
    x = np.linspace(-1.0, 1.0, 300).astype(np.float32)
    p, s = gather_quantize4_blocks(jnp.asarray(x), jnp.asarray([1], jnp.int32),
                                   chunk_words)
    n_last = 300 - 256
    wire = q4_encode_chunk(np.asarray(p)[0], np.asarray(s)[0],
                           n_last, chunk_words)
    got = np.frombuffer(q4_decode_chunk(wire, "float32"), np.float32)
    assert got.shape == (n_last,)
    assert np.max(np.abs(got - x[256:300])) <= np.abs(x[256:]).max() / 14.0


def test_q4_wire_roughly_halves_q8():
    rng = np.random.default_rng(2)
    chunk_words = 1024
    x = jnp.asarray(rng.normal(size=(chunk_words,)).astype(np.float32))
    idx = jnp.asarray([0], jnp.int32)
    from repro.kernels.ops import gather_quantize_blocks
    q, s8 = gather_quantize_blocks(x, idx, chunk_words)
    p, s4 = gather_quantize4_blocks(x, idx, chunk_words)
    w8 = q8_encode_chunk(np.asarray(q)[0], np.asarray(s8)[0], chunk_words)
    w4 = q4_encode_chunk(np.asarray(p)[0], np.asarray(s4)[0], chunk_words)
    assert len(w8) / len(w4) >= 1.8


# -------------------------------------------------------- entropy codec --
def test_entropy_codec_roundtrips():
    smooth = np.sin(np.linspace(0, 20, 4096)).astype(np.float32).tobytes()
    z = entropy_encode_bytes(smooth, itemsize=4)
    assert entropy_decode_bytes(z) == smooth
    assert len(z) < len(smooth)          # byte-plane shuffle pays on f32
    # int8-ish payloads and odd lengths use stride 1
    q = bytes(range(256)) * 3 + b"\x01"
    z = entropy_encode_bytes(q, itemsize=1)
    assert entropy_decode_bytes(z) == q
    # itemsize not dividing the length falls back to stride 1, still exact
    odd = os.urandom(1001)
    z = entropy_encode_bytes(odd, itemsize=4)
    assert entropy_decode_bytes(z) == odd


def test_entropy_decode_rejects_bad_magic():
    with pytest.raises(ValueError):
        entropy_decode_bytes(b"\x00\x01" + b"1234" + b"x")


def test_decode_wire_chunk_dispatch():
    x = np.arange(16, dtype=np.float32)
    raw = x.tobytes()
    assert decode_wire_chunk(raw, "raw", "float32") == raw
    z = entropy_encode_bytes(raw, itemsize=4)
    assert decode_wire_chunk(z, "raw+z", "float32") == raw
    p, s = gather_quantize4_blocks(jnp.asarray(x), jnp.asarray([0], jnp.int32),
                                   16)
    wire = q4_encode_chunk(np.asarray(p)[0], np.asarray(s)[0], 16, 16)
    got = np.frombuffer(decode_wire_chunk(wire, "q4", "float32"), np.float32)
    assert np.max(np.abs(got - x)) <= np.abs(x).max() / 14.0
    zq = entropy_encode_bytes(wire, itemsize=1)
    assert decode_wire_chunk(zq, "q4+z", "float32") == \
        decode_wire_chunk(wire, "q4", "float32")


# ------------------------------------------------- adaptive selector --
def test_tracker_error_bound_partitions_chunks():
    """One leaf, three amplitude regimes -> three encoding groups, split
    exactly where the guaranteed bounds cross the atol."""
    cw = 256
    rng = np.random.default_rng(3)
    leaf = np.empty(8 * cw, np.float32)
    base = rng.uniform(-1.0, 1.0, leaf.shape).astype(np.float32)
    leaf[: 3 * cw] = 0.01 * base[: 3 * cw]      # 0.01/13.5  <= 1e-2 -> q4
    leaf[3 * cw: 6 * cw] = base[3 * cw: 6 * cw]  # 1/126     <= 1e-2 -> q8
    leaf[6 * cw:] = 100.0 * base[6 * cw:]        # 100/126   >  1e-2 -> raw
    tr = DeltaTracker(chunk_words=cw)
    d = tr.finalize(tr.delta_dispatch("p", jnp.asarray(leaf),
                                      error_bound=1e-2))
    groups = {g["enc"]: g for g in d["enc_groups"]}
    assert set(groups) == {"q4", "q8", "raw"}
    assert list(groups["q4"]["idx"]) == [0, 1, 2]
    assert list(groups["q8"]["idx"]) == [3, 4, 5]
    assert list(groups["raw"]["idx"]) == [6, 7]
    # the selector divisors leave margin over the true half-step bounds
    assert Q4_ATOL_DIV < 14.0 and Q8_ATOL_DIV < 254.0


def test_tracker_fixed_enc_still_single_group():
    tr = DeltaTracker(chunk_words=256)
    x = jnp.asarray(np.ones(512, np.float32))
    d = tr.finalize(tr.delta_dispatch("p", x, quantize=True))
    assert [g["enc"] for g in d["enc_groups"]] == ["q8"]
    assert d["changed_q"] is not None          # legacy fields kept


# ------------------------------------------- pipeline end to end --------
def _restore(store, key, shapes):
    like = {k: np.empty(s, np.float32) if s else np.int64(0)
            for k, s in shapes.items()}
    return store.get_tree(key, like=like)


def test_pipeline_error_bounds_end_to_end(tmp_path):
    rng = np.random.default_rng(4)
    store = CheckpointStore(os.path.join(str(tmp_path), "store"))
    pipe = CheckpointPipeline(store, chunk_words=1024, full_every=4,
                              async_stage=False,
                              error_bounds={"mu": 1e-2})
    mus, ws = [], []
    for i in range(3):
        mu = (0.02 * rng.normal(size=4096)).astype(np.float32)
        w = rng.normal(size=2048).astype(np.float32)
        pipe.submit(f"ck{i}", {"mu": jnp.asarray(mu), "w": jnp.asarray(w),
                               "step": i}, block=True)
        mus.append(mu)
        ws.append(w)
    pipe.close()
    for i in range(3):
        out = _restore(store, f"ck{i}",
                       {"mu": (4096,), "w": (2048,), "step": None})
        # bounded slot restores within the declared atol...
        assert np.max(np.abs(out["mu"] - mus[i])) <= 1e-2
        # ...every other slot stays bit-identical
        assert np.array_equal(out["w"], ws[i])
        assert int(out["step"]) == i
    m0 = store.resolve_manifest("ck0")
    by_path = {lf["path"]: lf for lf in m0["leaves"]}
    assert by_path["['mu']"]["leaf_enc"] == "eb:0.01"
    assert set(by_path["['mu']"]["enc"]) <= {"q4", "q8", "raw",
                                             "q4+z", "q8+z", "raw+z"}
    assert set(by_path["['mu']"]["enc"]) & {"q4", "q4+z"}
    assert "enc" not in by_path["['w']"] or \
        all(e == "raw" for e in by_path["['w']"]["enc"])
    # the RAW delta manifest carries per-chunk encodings in denc...
    raw1 = {lf["path"]: lf for lf in
            store.get_manifest("ck1")["leaves"]}["['mu']"]
    if raw1.get("delta"):                    # noise may leave chunks equal
        assert set(raw1["denc"].values()) <= {"q4", "q8", "q4+z", "q8+z"}
    # ...and the resolved view inherits them into the full enc list
    m1 = store.resolve_manifest("ck1")
    lf1 = {lf["path"]: lf for lf in m1["leaves"]}["['mu']"]
    assert set(lf1["enc"]) <= {"q4", "q8", "q4+z", "q8+z"}
    mix = store.encoding_mix("ck2")
    assert any(e.startswith("q4") for e in mix)
    assert "raw" in mix


def test_pipeline_policy_change_forces_full(tmp_path):
    store = CheckpointStore(os.path.join(str(tmp_path), "store"))
    x = np.linspace(0, 0.01, 2048).astype(np.float32)
    pipe = CheckpointPipeline(store, chunk_words=1024, full_every=64,
                              async_stage=False, error_bounds={"mu": 1e-2})
    pipe.submit("a", {"mu": jnp.asarray(x)}, block=True)
    pipe.submit("b", {"mu": jnp.asarray(x + 1e-5)}, block=True)
    assert store.get_manifest("b")["kind"] == "delta"
    # same scope, new bound -> the policy string in the structure signature
    # flips -> forced full (mixed-bound chunk inheritance would be unsound)
    pipe.error_bounds = {"mu": 1e-3}
    pipe.submit("c", {"mu": jnp.asarray(x + 2e-5)}, block=True)
    pipe.close()
    assert store.get_manifest("c")["kind"] == "full"
    lf = {l["path"]: l for l in
          store.get_manifest("c")["leaves"]}["['mu']"]
    assert lf["leaf_enc"] == "eb:0.001"


def test_entropy_stage_needs_writer(tmp_path):
    """Sync pipelines must NOT run the entropy stage (it would bill the
    training thread); async pipelines compress repetitive lossy chunks and
    report the cost as entropy_s."""
    const = np.full(4096, 0.005, np.float32)
    s1 = CheckpointStore(os.path.join(str(tmp_path), "sync"))
    p1 = CheckpointPipeline(s1, chunk_words=1024, async_stage=False,
                            error_bounds={"mu": 1e-2})
    p1.submit("k", {"mu": jnp.asarray(const)}, block=True)
    p1.close()
    lf = s1.resolve_manifest("k")["leaves"][0]
    assert all(not e.endswith("+z") for e in lf["enc"])
    assert all(st.get("entropy_s", 0.0) == 0.0 for st in p1.stats)

    s2 = CheckpointStore(os.path.join(str(tmp_path), "async"))
    p2 = CheckpointPipeline(s2, chunk_words=1024, async_stage=True,
                            error_bounds={"mu": 1e-2})
    p2.submit("k", {"mu": jnp.asarray(const)}, block=True)
    p2.drain()
    stats = p2.stats
    p2.close()
    lf = s2.resolve_manifest("k")["leaves"][0]
    assert any(e.endswith("+z") for e in lf["enc"])   # constants compress
    assert any(st.get("entropy_s", 0.0) > 0.0 for st in stats)
    out = _restore(s2, "k", {"mu": (4096,)})
    assert np.max(np.abs(out["mu"] - const)) <= 1e-2


def test_auto_full_every_tracks_store_calib(tmp_path):
    x = np.linspace(0, 1, 65536).astype(np.float32)
    # expensive manifest hops -> short chains (K clamps to the 2 floor)
    s1 = CheckpointStore(os.path.join(str(tmp_path), "hops"))
    s1.put_meta("store_calib", {"read_bps": 1e9, "hop_s": 1.0})
    p1 = CheckpointPipeline(s1, full_every="auto", async_stage=False)
    p1.submit("k0", {"w": jnp.asarray(x)}, block=True)
    p1.close()
    assert p1.full_every == 2
    # slow reads + near-free hops -> long chains (K clamps to the 64 cap)
    s2 = CheckpointStore(os.path.join(str(tmp_path), "cheap"))
    s2.put_meta("store_calib", {"read_bps": 1e3, "hop_s": 1e-9})
    p2 = CheckpointPipeline(s2, full_every="auto", async_stage=False)
    p2.submit("k0", {"w": jnp.asarray(x)}, block=True)
    p2.close()
    assert p2.full_every == 64
    assert any("full_every" in st for st in p2.stats)


# --------------------------------------------------- session surface --
def test_recordspec_error_bounds_validation():
    from repro.core.session import RecordSpec
    spec = RecordSpec(ckpt_error_bounds={"mu": 1e-2, "nu": 1e-3})
    assert spec.ckpt_error_bounds == (("mu", 0.01), ("nu", 0.001))
    spec = RecordSpec(ckpt_error_bounds=[("mu", 1e-2)])
    assert spec.ckpt_error_bounds == (("mu", 0.01),)
    with pytest.raises(ValueError):
        RecordSpec(ckpt_error_bounds="mu")        # bare string
    with pytest.raises(ValueError):
        RecordSpec(ckpt_error_bounds={"mu": 0.0})  # atol must be > 0
    with pytest.raises(ValueError):
        RecordSpec(ckpt_error_bounds={"": 1e-2})   # empty slot
    with pytest.raises(ValueError):
        RecordSpec(full_manifest_every="never")
    assert RecordSpec(full_manifest_every="auto").full_manifest_every \
        == "auto"


def test_quantize_slots_deprecation_warns(tmp_path):
    from repro.core.context import FlorContext, FlorDeprecationWarning
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ctx = FlorContext(str(tmp_path), mode="record", adaptive=False,
                          ckpt_quantize_slots=("mu",))
        ctx.finish()
    assert any(issubclass(x.category, FlorDeprecationWarning) and
               "ckpt_error_bounds" in str(x.message) for x in w)


def test_session_error_bounds_roundtrip(tmp_path):
    from repro.core.session import RecordSpec, Session
    rng = np.random.default_rng(5)
    tree = {"mu": np.asarray(0.02 * rng.normal(size=2048), np.float32),
            "w": np.asarray(rng.normal(size=1024), np.float32)}
    with Session(str(tmp_path), mode="record",
                 record=RecordSpec(adaptive=False,
                                   ckpt_error_bounds={"mu": 1e-2},
                                   full_manifest_every="auto")) as sess:
        ctx = sess.ctx
        assert ctx.pipeline.error_bounds == {"mu": 0.01}
        assert ctx.pipeline.full_every_auto
        for i in range(2):
            ctx.submit_checkpoint("train", f"ck{i}", tree, {})
        ctx.pipeline.drain()
        lf = {l["path"]: l for l in
              ctx.store.resolve_manifest("ck0")["leaves"]}
        assert lf["['mu']"]["leaf_enc"] == "eb:0.01"
        out = ctx.store.get_tree("ck1")
        for p, a in out.items():
            ref = tree["mu" if "mu" in p else "w"]
            err = np.max(np.abs(a - ref))
            assert err <= 1e-2 if "mu" in p else err == 0.0
