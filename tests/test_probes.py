"""core/probes.py: source-diff probe detection (paper section 3.2) — the
`--probe auto` tier. Added-line -> loop mapping across shifted line
numbers, named flor loops, inner vs outer classification, suspicious
non-additive edits, and the no-op fast path."""
import textwrap

from repro.core.probes import detect_probes, loop_spans

ANON = textwrap.dedent("""\
    import flor
    state = 0
    for epoch in range(8):
        for step in range(4):
            state = state + step
        print(epoch)
""")

NAMED = textwrap.dedent("""\
    import repro.flor as flor
    with flor.Session(run_dir) as sess:
        with sess.checkpointing(state=state) as ckpt:
            for epoch in sess.loop("epochs", range(8)):
                for s in sess.loop("train", range(4)):
                    ckpt.state = step(ckpt.state)
                flor.log("loss", 1.0)
""")


def _insert(src: str, after_contains: str, line: str) -> str:
    lines = src.splitlines(keepends=True)
    i = next(n for n, ln in enumerate(lines) if after_contains in ln)
    indent = lines[i][: len(lines[i]) - len(lines[i].lstrip())]
    return "".join(lines[: i + 1] + [indent + line + "\n"] + lines[i + 1:])


# --------------------------------------------------------------- fast path --
def test_noop_diff_fast_path():
    rep = detect_probes(ANON, ANON)
    assert rep.empty and not rep.added_lines and not rep.suspicious


def test_unparseable_identical_sources_never_parse():
    # the no-op fast path must not require valid Python
    garbage = "for for for ((("
    rep = detect_probes(garbage, garbage)
    assert rep.empty


# ----------------------------------------------------------- line mapping --
def test_added_line_maps_to_innermost_loop():
    probed = _insert(ANON, "state = state + step",
                     "flor.log('probe', state)")
    rep = detect_probes(ANON, probed)
    # innermost loop is the step loop at OLD line 4
    assert rep.probed_blocks == {"L4"}
    assert not rep.probed_outer
    assert not rep.suspicious


def test_mapping_survives_shifted_line_numbers():
    """Lines added ABOVE the loop shift every lineno in the new source; the
    block id must still name the loop's line in the RECORDED source."""
    shifted = "import os\nimport sys\n\n" + _insert(
        ANON, "state = state + step", "flor.log('probe', state)")
    rep = detect_probes(ANON, shifted)
    assert rep.probed_blocks == {"L4"}       # old lineno, not the new one


def test_outer_loop_probe_classified_outer():
    probed = _insert(ANON, "print(epoch)", "flor.log('per_epoch', state)")
    rep = detect_probes(ANON, probed)
    assert rep.probed_outer == {"L3"}
    assert not rep.probed_blocks


def test_named_flor_loops_probe_by_name():
    probed = _insert(NAMED, "ckpt.state = step(ckpt.state)",
                     "flor.log('grad', 1.0)")
    rep = detect_probes(NAMED, probed)
    assert rep.probed_blocks == {"train"}
    # outer probe in the epochs loop -> named outer
    probed = _insert(NAMED, 'flor.log("loss", 1.0)',
                     "flor.log('embed', 2.0)")
    rep = detect_probes(NAMED, probed)
    assert rep.probed_outer == {"epochs"}
    assert not rep.probed_blocks


def test_named_mapping_survives_shift():
    shifted = "import json\n" + _insert(
        NAMED, "ckpt.state = step(ckpt.state)", "flor.log('grad', 1.0)")
    rep = detect_probes(NAMED, shifted)
    assert rep.probed_blocks == {"train"}


def test_line_outside_any_loop_is_ignored():
    probed = ANON + "flor.log('final', state)\n"
    rep = detect_probes(ANON, probed)
    assert rep.empty and len(rep.added_lines) == 1


# ------------------------------------------------------------- suspicious --
def test_replace_and_delete_are_suspicious():
    changed = ANON.replace("state = state + step", "state = state * step")
    rep = detect_probes(ANON, changed)
    assert rep.empty
    assert len(rep.suspicious) == 1 and rep.suspicious[0]["tag"] == "replace"

    deleted = ANON.replace("    print(epoch)\n", "")
    rep = detect_probes(ANON, deleted)
    assert rep.empty
    assert any(s["tag"] == "delete" for s in rep.suspicious)


def test_suspicious_and_added_coexist():
    edited = _insert(ANON.replace("print(epoch)", "print('e', epoch)"),
                     "state = state + step", "flor.log('p', state)")
    rep = detect_probes(ANON, edited)
    assert rep.probed_blocks == {"L4"}
    assert rep.suspicious


# ------------------------------------------------------------- loop spans --
def test_loop_spans_names_and_depth():
    spans = loop_spans(NAMED)
    by_name = {s.name: s for s in spans}
    assert by_name["epochs"].depth == 0
    assert by_name["train"].depth == 1


def test_loop_depth_resets_inside_functions():
    src = textwrap.dedent("""\
        def helper():
            for i in range(3):
                pass
        for epoch in range(8):
            helper()
    """)
    spans = loop_spans(src)
    assert all(s.depth == 0 for s in spans)
