"""Minimal hypothesis-compatible property-testing shim.

The real `hypothesis` package is not installable in this offline container,
so this module provides the same @given/strategies surface for the subset we
use, driving each property with deterministic seeded random examples
(shrinking omitted). Tests read exactly like hypothesis tests and would run
unmodified under the real library.
"""
from __future__ import annotations

import functools
import inspect
import random

DEFAULT_EXAMPLES = 25


class Strategy:
    def __init__(self, fn):
        self._fn = fn

    def example(self, rng: random.Random):
        return self._fn(rng)

    def map(self, f):
        return Strategy(lambda rng: f(self._fn(rng)))

    def filter(self, pred, tries=100):
        def gen(rng):
            for _ in range(tries):
                v = self._fn(rng)
                if pred(v):
                    return v
            raise ValueError("filter exhausted")
        return Strategy(gen)


class strategies:
    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0):
        return Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return Strategy(lambda rng: rng.choice(seq))

    @staticmethod
    def lists(elem: Strategy, min_size=0, max_size=10):
        def gen(rng):
            n = rng.randint(min_size, max_size)
            return [elem.example(rng) for _ in range(n)]
        return Strategy(gen)

    @staticmethod
    def tuples(*elems):
        return Strategy(lambda rng: tuple(e.example(rng) for e in elems))

    @staticmethod
    def composite(fn):
        @functools.wraps(fn)
        def builder(*args, **kw):
            def gen(rng):
                draw = lambda s: s.example(rng)
                return fn(draw, *args, **kw)
            return Strategy(gen)
        return builder


st = strategies


def given(*g_args, **g_kw):
    def deco(test_fn):
        sig = inspect.signature(test_fn)
        names = list(sig.parameters)

        @functools.wraps(test_fn)
        def wrapper(*call_args, **call_kw):
            rng = random.Random(0xF10B + hash(test_fn.__name__) % 10_000)
            for ex in range(DEFAULT_EXAMPLES):
                drawn_pos = [s.example(rng) for s in g_args]
                drawn_kw = {k: s.example(rng) for k, s in g_kw.items()}
                try:
                    test_fn(*call_args, *drawn_pos, **call_kw, **drawn_kw)
                except Exception as e:
                    raise AssertionError(
                        f"property failed on example {ex}: pos={drawn_pos} "
                        f"kw={drawn_kw}: {e}") from e

        # hide drawn params from pytest's fixture resolution
        drawn_names = set(g_kw) | set(names[: len(g_args)])
        remaining = [p for n, p in sig.parameters.items()
                     if n not in drawn_names]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper
    return deco


def settings(**_kw):
    def deco(fn):
        return fn
    return deco
