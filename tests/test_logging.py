"""Background logging subsystem (repro.logging): async/sync bit-identity,
crash-safe segments (torn tails, seq resume), flat<->segment layout
transitions, large-value spill refs, FlorLogValueWarning, the bounded
tail-seq fix, replay-merge fidelity over segmented worker logs, and the
shared epsilon budget between logging and checkpointing."""
import json
import os
import shutil
import warnings

import numpy as np
import pytest

import repro.flor as flor
from repro.checkpoint import CheckpointStore
from repro.core.adaptive import AdaptiveController
from repro.core.context import FingerprintLog
from repro.core.query import merge_replay_logs
from repro.logging import (FlorLogValueWarning, SegmentSink, jsonable,
                           list_segments, read_stream, remove_stream,
                           reset_warned_keys, segment_path, tail_seq)


def _rows(path):
    return FingerprintLog.read(path)


def _payload(rows):
    """Rows minus nothing — the exact (epoch, seq, key, value) contract."""
    return [(r["epoch"], r["seq"], r["key"], json.dumps(r["value"],
                                                        sort_keys=True))
            for r in rows]


MIXED = [0, 1.5, "s", True, None, [1, [2, 3]], {"a": 1, "b": [2]},
         np.float64(3.25), np.arange(4.0)]


def _log_mixed(log, jnp=None):
    vals = list(MIXED)
    if jnp is not None:
        vals += [jnp.float32(7.5), jnp.arange(6.0)]
    for i, v in enumerate(vals):
        log.log(i % 3, f"k{i}", v)
    return len(vals)


# --------------------------------------------------- mode bit-identity ------
def test_async_rows_bit_identical_to_sync(tmp_path):
    import jax.numpy as jnp
    ps = str(tmp_path / "sync.jsonl")
    pa = str(tmp_path / "async.jsonl")
    ls = FingerprintLog(ps, async_log=False)
    la = FingerprintLog(pa, async_log=True)
    n = _log_mixed(ls, jnp)
    _log_mixed(la, jnp)
    ls.close()
    la.close()
    assert os.path.isfile(ps) and os.path.isdir(pa)   # two layouts...
    rs, ra = _rows(ps), _rows(pa)
    assert len(rs) == n
    assert _payload(rs) == _payload(ra)               # ...same rows, exactly


def test_async_value_snapshot_semantics(tmp_path):
    """Values mutated AFTER flor.log must not change what was logged —
    numpy arrays are memcpy'd and containers frozen at enqueue."""
    p = str(tmp_path / "log.jsonl")
    log = FingerprintLog(p, async_log=True)
    arr = np.arange(3.0)
    box = {"x": [1, 2]}
    log.log(0, "arr", arr)
    log.log(0, "box", box)
    arr[:] = -1.0
    box["x"].append(99)
    log.close()
    vals = {r["key"]: r["value"] for r in _rows(p)}
    assert vals["arr"] == [0.0, 1.0, 2.0]
    assert vals["box"] == {"x": [1, 2]}


# ------------------------------------------------------- segment layout -----
def test_segments_roll_and_seal(tmp_path):
    p = str(tmp_path / "log.jsonl")
    log = FingerprintLog(p, async_log=True, roll_bytes=200)
    for i in range(30):
        log.log(0, "k", i)
    log.close()
    segs = list_segments(p)
    assert len(segs) > 1                              # rolled
    for _n, seg in segs:
        with open(seg) as f:
            last = [ln for ln in f.read().splitlines() if ln.strip()][-1]
        assert "__seal__" in json.loads(last)         # all sealed on close
    assert [r["value"] for r in _rows(p)] == list(range(30))
    assert [r["seq"] for r in _rows(p)] == list(range(30))


def test_reader_skips_seal_and_merges_in_order(tmp_path):
    d = str(tmp_path / "stream")
    sink = SegmentSink(d, roll_bytes=80)
    for i in range(10):
        sink.append(json.dumps({"epoch": 0, "seq": i, "key": "k",
                                "value": i}) + "\n", i)
    sink.close()
    rows = read_stream(d)
    assert [r["seq"] for r in rows] == list(range(10))


# ----------------------------------------------------------- crash safety ---
def _tear(path):
    """Append a torn half-line, as a writer killed mid-write leaves it."""
    with open(path, "a") as f:
        f.write('{"epoch": 9, "seq": 99999, "key": "torn", "val')


def test_torn_tail_skipped_and_seq_resumes(tmp_path):
    p = str(tmp_path / "log.jsonl")
    log = FingerprintLog(p, async_log=True)
    for i in range(5):
        log.log(0, "k", i)
    # simulate SIGKILL: rows drained to disk but no clean close/seal
    log.drain()
    last_seg = list_segments(p)[-1][1]
    _tear(last_seg)
    before = _payload(_rows(p))
    assert len(before) == 5                           # torn tail invisible
    assert tail_seq(p) == 5                           # resume point correct
    log2 = FingerprintLog(p, async_log=True)          # crash-restart resume
    log2.log(1, "k", 5)
    log2.close()
    rows = _rows(p)
    assert _payload(rows[:5]) == before               # old rows untouched
    assert rows[-1]["seq"] == 5                       # no duplicate seq
    # the resumed writer started a FRESH segment (never appends to a
    # possibly-torn one)
    assert len(list_segments(p)) >= 2


def test_wholly_torn_trailing_segment_steps_back(tmp_path):
    p = str(tmp_path / "log.jsonl")
    log = FingerprintLog(p, async_log=True, roll_bytes=60)
    for i in range(6):
        log.log(0, "k", i)
    log.close()
    # a crashed successor segment whose every line tore
    n = list_segments(p)[-1][0] + 1
    with open(segment_path(p, n), "w") as f:
        f.write('{"epoch": 0, "seq": 6, "key": "k", "va')
    assert len(_rows(p)) == 6
    assert tail_seq(p) == 6                           # steps back past it


def test_mid_file_corruption_raises_not_skips(tmp_path):
    """A torn TAIL is recoverable; garbage in the MIDDLE of a log is real
    corruption and must raise — silently dropping rows would let the
    deferred check pass on rows it never compared."""
    p = str(tmp_path / "record.jsonl")
    good = [json.dumps({"epoch": 0, "seq": i, "key": "k", "value": i})
            for i in range(3)]
    with open(p, "w") as f:
        f.write(good[0] + "\n@@corrupt@@\n" + good[1] + "\n")
    with pytest.raises(ValueError, match="corrupt log line"):
        FingerprintLog.read(p)
    with open(p, "w") as f:                           # torn tail: fine
        f.write("\n".join(good) + "\n" + '{"torn": ')
    assert len(FingerprintLog.read(p)) == 3


def test_unsealed_segment_reads_fine(tmp_path):
    d = str(tmp_path / "stream")
    sink = SegmentSink(d)
    sink.append(json.dumps({"epoch": 0, "seq": 0, "key": "k",
                            "value": 1}) + "\n", 0)
    # no close(): segment has no footer — exactly the post-kill state
    assert [r["value"] for r in read_stream(d)] == [1]
    assert tail_seq(d) == 1


# ------------------------------------------------- replay merge fidelity ----
def test_replay_merge_bit_identical_across_torn_recovery(tmp_path):
    run = str(tmp_path / "run")
    logs = os.path.join(run, "logs")

    def worker(pid, epochs, async_log):
        lg = FingerprintLog(os.path.join(logs, f"replay_p{pid}.jsonl"),
                            fresh=True, async_log=async_log)
        for e in epochs:
            for s in range(3):
                lg.log(e, "probe", e * 10 + s)
            lg.log(e, "loss", float(e))
        lg.drain() if async_log else None
        return lg

    # single-worker reference (sync flat log)
    ref = worker(9, [0, 1, 2, 3], async_log=False)
    ref.close()
    expected = merge_replay_logs(run, [("replay_p9", [0, 1, 2, 3])],
                                 out_path=None)
    # two segmented workers; p1 killed mid-write after its rows drained
    w0 = worker(0, [0, 2], async_log=True)
    w0.close()
    w1 = worker(1, [1, 3], async_log=True)
    w1.drain()
    _tear(list_segments(os.path.join(logs, "replay_p1.jsonl"))[-1][1])
    merged = merge_replay_logs(run, [("replay_p0", [0, 2]),
                                     ("replay_p1", [1, 3])], out_path=True)
    assert merged == expected                         # bit-identical
    # and the merged flat artifact round-trips through the same reader
    assert FingerprintLog.read(os.path.join(logs, "merged_replay.jsonl")) \
        == expected


# -------------------------------------------------- layout transitions ------
def test_flat_run_resumes_into_segments(tmp_path):
    p = str(tmp_path / "record.jsonl")
    sync = FingerprintLog(p, async_log=False)
    sync.log(0, "a", 1)
    sync.log(0, "b", 2)
    sync.close()
    resumed = FingerprintLog(p, async_log=True)       # async resume of a
    resumed.log(1, "a", 3)                            # sync-era run dir
    resumed.close()
    assert os.path.isdir(p)                           # migrated in place
    rows = _rows(p)
    assert [r["seq"] for r in rows] == [0, 1, 2]
    assert [r["value"] for r in rows] == [1, 2, 3]


def test_interrupted_migration_recovers(tmp_path):
    """A crash BETWEEN the two migration renames leaves rows in the
    .migrate leftover; the next open must adopt them, not strand them."""
    p = str(tmp_path / "record.jsonl")
    sync = FingerprintLog(p, async_log=False)
    sync.log(0, "a", 1)
    sync.close()
    os.replace(p, p + ".migrate")                     # first rename, then die
    resumed = FingerprintLog(p, async_log=True)
    resumed.log(1, "a", 2)
    resumed.close()
    rows = _rows(p)
    assert [r["value"] for r in rows] == [1, 2]
    assert [r["seq"] for r in rows] == [0, 1]         # seq saw the old rows
    assert not os.path.exists(p + ".migrate")


def test_sync_reopen_of_segmented_stream_stays_segmented(tmp_path):
    p = str(tmp_path / "record.jsonl")
    a = FingerprintLog(p, async_log=True)
    a.log(0, "k", 1)
    a.close()
    s = FingerprintLog(p, async_log=False)            # layout is a property
    s.log(1, "k", 2)                                  # of the run dir, not
    s.close()                                         # the reopening process
    assert os.path.isdir(p)
    assert [r["value"] for r in _rows(p)] == [1, 2]
    assert [r["seq"] for r in _rows(p)] == [0, 1]


def test_fresh_rotates_either_layout(tmp_path):
    p = str(tmp_path / "replay_p0.jsonl")
    a = FingerprintLog(p, async_log=True)
    a.log(0, "k", "old")
    a.close()
    b = FingerprintLog(p, fresh=True, async_log=True)
    b.log(0, "k", "new")
    b.close()
    rows = _rows(p)
    assert len(rows) == 1 and rows[0]["value"] == "new"
    assert rows[0]["seq"] == 0
    remove_stream(p)
    assert not os.path.exists(p)


# ------------------------------------------------------- value handling -----
def test_warn_once_per_key_names_type(tmp_path):
    class Gizmo:
        def __repr__(self):
            return "<gizmo>"

    reset_warned_keys()
    p = str(tmp_path / "log.jsonl")
    log = FingerprintLog(p, async_log=False)
    with pytest.warns(FlorLogValueWarning, match="Gizmo") as rec:
        log.log(0, "widget", Gizmo())
        log.log(0, "widget", Gizmo())                 # same key: no 2nd warn
    assert len([w for w in rec if w.category is FlorLogValueWarning]) == 1
    with pytest.warns(FlorLogValueWarning, match="other"):
        log.log(0, "other", Gizmo())                  # new key warns again
    log.close()
    assert [r["value"] for r in _rows(p)] == ["<gizmo>"] * 3


def test_jsonable_known_types_do_not_warn():
    reset_warned_keys()
    with warnings.catch_warnings():
        warnings.simplefilter("error", FlorLogValueWarning)
        for v in MIXED:
            jsonable(v, "k")


@pytest.mark.parametrize("async_log", [False, True])
def test_large_value_spills_to_store_ref(tmp_path, async_log):
    store = CheckpointStore(str(tmp_path / "store"))
    p = str(tmp_path / ("a.jsonl" if async_log else "s.jsonl"))
    log = FingerprintLog(p, async_log=async_log, spill_bytes=256,
                         store=store, stream="record")
    big = np.arange(1024, dtype=np.float64)           # 8 KiB > threshold
    small = np.arange(4, dtype=np.float64)
    log.log(0, "big", big)
    log.log(0, "small", small)
    log.close()
    rows = {r["key"]: r["value"] for r in _rows(p)}
    assert rows["small"] == small.tolist()            # under threshold: inline
    ref = rows["big"]
    assert ref["ref"] == "logref__record__00000000"   # deterministic key
    assert ref["shape"] == [1024] and ref["nbytes"] == 8192
    (_path, arr), = store.get_tree(ref["ref"]).items()
    assert np.array_equal(np.asarray(arr).reshape(-1), big)


def test_spill_rows_diff_by_digest_in_deferred_check(tmp_path):
    """Record and replay spill under different stream names — the deferred
    check must compare spilled rows by content digest, so a faithful
    replay passes and a divergent one is an anomaly."""
    from repro.core.fingerprint import deferred_check
    store = CheckpointStore(str(tmp_path / "store"))
    big = np.arange(512, dtype=np.float64)
    logs = {}
    for stream, vals in (("record", [big]),
                         ("replay_ok", [big.copy()]),
                         ("replay_bad", [big + 1.0])):
        p = str(tmp_path / f"{stream}.jsonl")
        lg = FingerprintLog(p, async_log=True, spill_bytes=64,
                            store=store, stream=stream)
        for v in vals:
            lg.log(0, "hist", v)
        lg.close()
        logs[stream] = p
    ok = deferred_check(logs["record"], [logs["replay_ok"]])
    assert ok.ok and ok.compared == 1
    bad = deferred_check(logs["record"], [logs["replay_bad"]])
    assert not bad.ok and bad.anomalies[0]["key"] == "hist"


def test_spill_ref_identical_across_modes(tmp_path):
    big = np.arange(512, dtype=np.float64)
    vals = []
    for mode, name in ((False, "s"), (True, "a")):
        store = CheckpointStore(str(tmp_path / f"store_{name}"))
        p = str(tmp_path / f"{name}.jsonl")
        log = FingerprintLog(p, async_log=mode, spill_bytes=64,
                             store=store, stream="record")
        log.log(0, "big", big)
        log.close()
        vals.append(_payload(_rows(p)))
    assert vals[0] == vals[1]


# ----------------------------------------------------- bounded tail seq -----
def test_flat_tail_seq_bounded_window(tmp_path, monkeypatch):
    from repro.logging import segment as seg_mod
    p = str(tmp_path / "record.jsonl")
    log = FingerprintLog(p, async_log=False)
    for i in range(300):
        log.log(0, "k", "x" * 40)
    log.close()
    reads = []
    orig = seg_mod._flat_tail_seq

    real_open = open

    def counting_open(path, mode="r", *a, **kw):
        f = real_open(path, mode, *a, **kw)
        if path == p and "r" in mode:
            reads.append(f)
        return f

    monkeypatch.setattr("builtins.open", counting_open)
    assert seg_mod.tail_seq(p) == 300
    monkeypatch.undo()
    # bounded: one tail window was enough — no full-file line parse
    assert len(reads) == 1
    # resume through the public surface agrees
    log2 = FingerprintLog(p, async_log=False)
    log2.log(1, "k", "y")
    log2.close()
    assert _rows(p)[-1]["seq"] == 300


def test_flat_tail_seq_widens_past_garbage_tail(tmp_path):
    p = str(tmp_path / "record.jsonl")
    with open(p, "w") as f:
        f.write(json.dumps({"epoch": 0, "seq": 7, "key": "k",
                            "value": 0}) + "\n")
        f.write("not json\n" * 8000)                  # > one tail window
    assert tail_seq(p) == 8


# ------------------------------------------------ shared epsilon budget -----
def test_logging_cost_draws_down_epsilon():
    ctl = AdaptiveController(epsilon=0.1)
    for _ in range(10):
        ctl.observe_execution("train", 1.0)           # 10 s of compute
    assert ctl.effective_epsilon() == pytest.approx(0.1)
    ctl.observe_logging(0.25, 1000)                   # 2.5% overhead logged
    assert ctl.effective_epsilon() == pytest.approx(0.075)
    ctl.observe_logging(1.0, 4000)                    # blow the budget
    assert ctl.effective_epsilon() == 0.0
    snap = ctl.snapshot()
    assert snap["log_s"] == pytest.approx(1.25)
    assert snap["log_bytes"] == 5000
    assert snap["epsilon_effective"] == 0.0


def test_heavy_logging_suppresses_materialization():
    ctl = AdaptiveController(epsilon=0.1)
    ctl.observe_execution("train", 1.0)
    ctl.observe_materialization("train", 0.01)        # cheap ckpt: M/C small
    ctl.observe_execution("train", 1.0)
    assert ctl.should_materialize("train")
    ctl.observe_logging(10.0)                         # logging ate the budget
    assert not ctl.should_materialize("train")


# --------------------------------------------------- session integration ----
def _state(x=0.0):
    return {"w": np.arange(6.0) + x, "b": np.zeros(3) + x}


def _step(s):
    return {k: v + 1.0 for k, v in s.items()}


def _record(run, async_log, epochs=3, steps=2):
    with flor.Session(run, mode="record",
                      record=flor.RecordSpec(
                          adaptive=False, async_log=async_log)) as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(epochs)):
                for _ in sess.loop("train", range(steps)):
                    ckpt.state = _step(ckpt.state)
                sess.log("loss", float(ckpt.state["w"][0]))
                sess.log("w0", ckpt.state["w"])


def test_session_log_records_identical_between_modes(tmp_path):
    ra, rs = str(tmp_path / "a"), str(tmp_path / "s")
    _record(ra, async_log=True)
    _record(rs, async_log=False)
    pa = os.path.join(ra, "logs", "record.jsonl")
    ps = os.path.join(rs, "logs", "record.jsonl")
    assert os.path.isdir(pa) and os.path.isfile(ps)
    strip = lambda rows: [(r["epoch"], r["seq"], r["key"],
                           json.dumps(r["value"])) for r in rows]
    assert strip(flor.FingerprintLog.read(pa)) \
        == strip(flor.FingerprintLog.read(ps))
    # the cross-run query surface sees both the same way
    ka = [(r["key"], r["epoch"]) for r in flor.log_records(ra)]
    ks = [(r["key"], r["epoch"]) for r in flor.log_records(rs)]
    assert ka == ks


def test_session_replay_after_torn_record_tail(tmp_path):
    run = str(tmp_path / "run")
    _record(run, async_log=True)
    _tear(list_segments(os.path.join(run, "logs", "record.jsonl"))[-1][1])
    with flor.Session(run, mode="replay",
                      replay=flor.ReplaySpec(probed={"train"})) as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(3)):
                for _ in sess.loop("train", range(2)):
                    ckpt.state = _step(ckpt.state)
                sess.log("loss", float(ckpt.state["w"][0]))
                sess.log("w0", ckpt.state["w"])
    rec, reps = flor.run_logs(run)
    res = flor.deferred_check(rec, reps)
    assert res.ok, res.anomalies
    assert res.compared == 6                          # 3 epochs x 2 keys


def test_controller_snapshot_persists_logging_stats(tmp_path):
    run = str(tmp_path / "run")
    _record(run, async_log=True)
    store = CheckpointStore(os.path.join(run, "store"))
    snap = store.get_meta("controller_record_p0")
    assert snap is not None and "log_s" in snap and "log_bytes" in snap
    assert snap["log_bytes"] > 0                      # bytes were accounted


def test_container_with_array_leaves_serializes_in_both_modes(tmp_path):
    """json.dumps must not crash (deferred, at close) on containers whose
    LEAVES are arrays/objects — they lower through json_default."""
    class Odd:
        def __repr__(self):
            return "<odd>"

    import jax.numpy as jnp
    reset_warned_keys()
    payloads = []
    for mode, name in ((False, "s"), (True, "a")):
        p = str(tmp_path / f"{name}.jsonl")
        log = FingerprintLog(p, async_log=mode)
        log.log(0, "metrics", {"grad": np.arange(3.0), "n": 2})
        log.log(0, "jax_nested", {"w": jnp.arange(3.0)})
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", FlorLogValueWarning)
            log.log(0, "nested_odd", [1, Odd()])
        log.close()                                   # must not raise
        payloads.append(_payload(_rows(p)))
    assert payloads[0] == payloads[1]
    vals = {r["key"]: r["value"] for r in _rows(str(tmp_path / "a.jsonl"))}
    assert vals["metrics"] == {"grad": [0.0, 1.0, 2.0], "n": 2}
    # a nested jax array lowers like a top-level one (numbers, not repr)
    assert vals["jax_nested"] == {"w": [0.0, 1.0, 2.0]}
    assert vals["nested_odd"] == [1, "<odd>"]


def test_zero_dim_numpy_snapshot_at_enqueue(tmp_path):
    p = str(tmp_path / "log.jsonl")
    log = FingerprintLog(p, async_log=True)
    acc = np.array(1.0)                               # 0-d, mutable
    log.log(0, "acc", acc)
    acc += 41.0
    log.close()
    assert _rows(p)[0]["value"] == 1.0                # value at log time


def test_user_dicts_with_ref_key_still_compared_exactly(tmp_path):
    from repro.core.fingerprint import deferred_check
    rec = str(tmp_path / "record.jsonl")
    rep = str(tmp_path / "replay_p0.jsonl")
    for path, ref in ((rec, "model-a"), (rep, "model-b")):
        lg = FingerprintLog(path, async_log=False)
        lg.log(0, "cfg", {"ref": ref})                # user dict, not a spill
        lg.close()
    res = deferred_check(rec, [rep])
    assert not res.ok and res.anomalies[0]["key"] == "cfg"


def test_finish_finalizes_registry_despite_log_close_error(tmp_path):
    run = str(tmp_path / "run")
    with pytest.raises(RuntimeError, match="boom"):
        with flor.Session(run, mode="record",
                          record=flor.RecordSpec(adaptive=False)) as sess:
            ctx = sess.ctx
            orig_close = ctx.log.close

            def exploding_close():
                orig_close()
                raise RuntimeError("boom")

            ctx.log.close = exploding_close
            sess.log("loss", 1.0)
    # the run record must still have been finalized, not left 'running'
    rec = ctx.registry.get(ctx.run_id)
    assert rec is not None and rec["status"] in ("finished", "failed")


def test_close_seals_good_rows_despite_stage_error(tmp_path):
    p = str(tmp_path / "log.jsonl")
    log = FingerprintLog(p, async_log=True)
    log.log(0, "k", 1)
    log.drain()
    # poison the stage so close() raises AFTER the good row landed
    log._stage._err = RuntimeError("disk on fire")
    with pytest.raises(RuntimeError, match="disk on fire"):
        log.close()
    # the durable prefix is sealed: tail_seq answers from the footer
    with open(list_segments(p)[-1][1]) as f:
        assert "__seal__" in f.read().splitlines()[-1]
    assert [r["value"] for r in _rows(p)] == [1]


def test_backpressure_queue_never_drops(tmp_path):
    p = str(tmp_path / "log.jsonl")
    log = FingerprintLog(p, async_log=True, queue_depth=2)
    for i in range(500):                              # far beyond the queue
        log.log(0, "k", i)
    log.close()
    assert [r["value"] for r in _rows(p)] == list(range(500))
