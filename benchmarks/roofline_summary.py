"""Roofline table (ours): reads the dry-run matrix JSON (launch/dryrun.py
--all --out results/dryrun_single.json) and emits the per-cell terms.
Does NOT compile anything itself — run the dry-run first."""
from __future__ import annotations

import json
import os

from benchmarks.common import Rows

_BASE = os.path.join(os.path.dirname(__file__), "..", "results")
DEFAULT = os.path.join(_BASE, "final", "dryrun_single.json")
FALLBACK = os.path.join(_BASE, "dryrun_single.json")


def run(rows: Rows, path: str = DEFAULT):
    if not os.path.exists(path):
        path = FALLBACK
    if not os.path.exists(path):
        rows.add("roofline", "status", "dry-run results not found",
                 "run: python -m repro.launch.dryrun --all --out " + path)
        return
    with open(path) as f:
        results = json.load(f)
    for r in results:
        cell = f"{r['arch']}/{r['shape']}"
        if r["status"] != "ok":
            rows.add("roofline", cell, r["status"],
                     r.get("reason", r.get("error", ""))[:60])
            continue
        rl = r["roofline"]
        terms = {"compute": rl["compute_s"], "memory": rl["memory_s"],
                 "collective": rl["collective_s"]}
        dom = max(terms, key=terms.get)
        rows.add("roofline", cell + "_dominant", dom,
                 f"c={terms['compute']:.3g};m={terms['memory']:.3g};"
                 f"n={terms['collective']:.3g}")


if __name__ == "__main__":
    run(Rows())
