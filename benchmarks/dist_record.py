"""True multi-process record bandwidth vs the single-process simulation.

The tentpole claim of distributed record: with the SAME total device count,
splitting the mesh across real host processes scales aggregate record
bandwidth — each process runs the fused fingerprint+gather+encode pass over
only ITS shards, concurrently on its own core(s), and publishes into its
own store shard pools; the only serialization left is the lead's v4 stitch
(a metadata write behind a file rendezvous).

Measured here as one 8-device (2, 4) mesh recorded two ways:

  * single  — 1 process simulating all 8 devices, serial fused pass;
  * fleet   — 2 real ``jax.distributed`` processes x 4 devices, concurrent
              local passes + crash-safe stitch rendezvous.

    speedup = single_wall / max(per-process fleet wall)

The gate is CORE-AWARE because the win comes from real parallelism: on a
single-core box two processes just timeslice, so the gate only reports; on
2-3 cores scheduler overhead caps the win (gate 1.1x); with >= 4 cores the
paper-faithful gate applies (>= 1.5x).
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys

from benchmarks.common import Rows

SMOKE = bool(os.environ.get("SMOKE"))
MESH_SHAPE = (2, 4)
N_PROCS = 2
SIDE = 512 if SMOKE else 1024         # three f32 (SIDE, SIDE) leaves
N_CKPTS = 3 if SMOKE else 5

_CORES = os.cpu_count() or 1
MIN_SPEEDUP = 1.5 if _CORES >= 4 else (1.1 if _CORES >= 2 else None)


def _child() -> dict:
    """One record process: ``--pid N`` of ``--nprocs M`` (M=1 -> the
    single-process baseline over all 8 devices, no rendezvous)."""
    pid = int(sys.argv[sys.argv.index("--pid") + 1])
    nprocs = int(sys.argv[sys.argv.index("--nprocs") + 1])
    local = (MESH_SHAPE[0] * MESH_SHAPE[1]) // nprocs
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={local}"
    os.environ["JAX_PLATFORMS"] = "cpu"

    import time

    import jax
    import numpy as np
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.checkpoint import CheckpointPipeline, CheckpointStore
    from repro.parallel.rendezvous import (ProcessGroup, StitchRendezvous,
                                           init_distributed)

    root = sys.argv[sys.argv.index("--root") + 1]
    dist = None
    if nprocs > 1:
        port = sys.argv[sys.argv.index("--port") + 1]
        group = init_distributed(f"127.0.0.1:{port}", pid, nprocs)
        # generous stitch deadline: on an oversubscribed box the peer may
        # still be COMPILING during the lead's first warm gather, and a
        # timeout would mark the checkpoint incomplete and poison the
        # measurement (the integrity check in _measure would then fail)
        dist = StitchRendezvous(root, "bench", group, timeout_s=600.0)
    else:
        group = ProcessGroup(0, 1)

    mesh = Mesh(np.array(jax.devices()).reshape(MESH_SHAPE),
                ("data", "model"))
    specs = {"win": P("data", "model"), "wout": P("model", "data"),
             "embed": P("data", "model")}

    def make_state(step):
        # dense noise + relative step: every element's bytes change
        # between checkpoints, identically in every process
        idx = np.arange(SIDE * SIDE,
                        dtype=np.float32).reshape(SIDE, SIDE)
        noise = np.sin(idx)
        out = {}
        for i, k in enumerate(sorted(specs)):
            arr = noise * ((i + 1) * (1.0 + 0.001 * (step + 3)))
            out[k] = jax.make_array_from_callback(
                arr.shape, NamedSharding(mesh, specs[k]),
                lambda b, a=arr: a[b])
        return out

    store = CheckpointStore(root)
    pipe = CheckpointPipeline(store, async_stage=False, mesh=mesh,
                              dist=dist)
    # warm both fingerprint variants (first-contact and delta) out of the
    # measured window
    pipe.submit("warm@0.0", make_state(-2), block=True)
    pipe.submit("warm@1.0", make_state(-1), block=True)
    t0 = time.perf_counter()
    for i in range(N_CKPTS):
        pipe.submit(f"train@{i}.0", make_state(i), block=True)
    wall = time.perf_counter() - t0
    pipe.close()
    if dist is not None:
        dist.arrive("bench.exit")
        dist.await_all("bench.exit")
    print(json.dumps({"pid": pid, "wall_s": wall}), flush=True)
    os._exit(0)


def _free_port() -> int:
    s = socket.socket()
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _spawn(pid: int, nprocs: int, root: str, port: int):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("JAX_PLATFORMS", None)
    # oversubscribed boxes: concurrent XLA compiles can starve a process
    # past the coordination service's stock 100s heartbeat window, and the
    # coordinator would abort the healthy peer mid-measurement
    env.setdefault("FLOR_DIST_HEARTBEAT_SLACK", "6")
    env["PYTHONPATH"] = os.pathsep.join(
        ["src", ".", env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    return subprocess.Popen(
        [sys.executable, "-m", "benchmarks.dist_record", "--child",
         "--pid", str(pid), "--nprocs", str(nprocs),
         "--root", root, "--port", str(port)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)


def _measure(nprocs: int, root: str) -> float:
    import shutil
    if os.path.isdir(root):
        shutil.rmtree(root)
    port = _free_port()
    procs = [_spawn(p, nprocs, root, port) for p in range(nprocs)]
    walls = []
    for p in procs:
        rc = p.wait(timeout=1200)
        out = p.stdout.read()
        if rc != 0:
            raise RuntimeError(f"dist_record child rc={rc}:"
                               f"\n{out[-2000:]}")
        walls.append(json.loads(out.strip().splitlines()[-1])["wall_s"])
    # integrity: every measured checkpoint must have stitched — a wall
    # that includes gather timeouts on incomplete checkpoints is not a
    # record-bandwidth measurement
    from repro.checkpoint import CheckpointStore
    store = CheckpointStore(root)
    assert store.get_meta("incomplete_ckpts") in (None, {"keys": []}), \
        "stitch deadline hit during measurement"
    keys = set(store.list_keys())
    for i in range(N_CKPTS):
        assert f"train_at_{i}.0" in keys, f"train@{i}.0 missing"
    # processes run concurrently: the fleet's wall is the slowest member
    return max(walls)


def run(rows: Rows):
    logical = 3 * SIDE * SIDE * 4 * N_CKPTS
    single_wall = _measure(1, "/tmp/bench_dist_record/single")
    fleet_wall = _measure(N_PROCS, "/tmp/bench_dist_record/fleet")
    single_bw = logical / single_wall
    fleet_bw = logical / fleet_wall
    speedup = fleet_bw / single_bw

    note = f"(2,4) mesh, {N_PROCS} real processes, {_CORES} core(s)"
    rows.add("dist_record", "record_bw_single_mbs",
             round(single_bw / 2**20, 1), "1 process x 8 devices")
    rows.add("dist_record", "record_bw_fleet_mbs",
             round(fleet_bw / 2**20, 1), note)
    rows.add("dist_record", "record_bw_speedup", round(speedup, 2),
             f"gate >= {MIN_SPEEDUP}x" if MIN_SPEEDUP
             else "no gate on 1 core (timeslicing)")
    rows.add("dist_record", "cores", _CORES, "os.cpu_count")

    if MIN_SPEEDUP is not None:
        assert speedup >= MIN_SPEEDUP, \
            (f"distributed record bandwidth {speedup:.2f}x < "
             f"{MIN_SPEEDUP}x on {_CORES} cores")


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        run(Rows())
