"""Benchmark driver: one harness per paper table/figure.

CSV to stdout, plus one ``BENCH_<module>.json`` artifact per harness at the
repo root — machine-readable results (metric rows + wall time + error
state) that CI and the acceptance gates consume, and that get committed so
a PR's measured numbers review alongside its code.

    python -m benchmarks.run                     # everything
    python -m benchmarks.run --only delta_pipeline,record_overhead
    python -m benchmarks.run --only delta_pipeline --strict   # CI: raise

``--strict`` turns a harness exception into a non-zero exit (the default
report-and-continue keeps one broken harness from hiding the others'
numbers on a full local sweep).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks.common import Rows

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _emit_json(name: str, rows: list, wall_s: float, error: str | None):
    """Write one BENCH_<name>.json at the repo root: the module's metric
    rows in emission order (values stay JSON-native — bools/ints/floats)."""
    out = {
        "bench": name,
        "smoke": bool(os.environ.get("SMOKE")),
        "wall_s": round(wall_s, 2),
        "error": error,
        "rows": [{"bench": b, "metric": m, "value": v, "note": n}
                 for b, m, v, n in rows],
    }
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", default="",
                    help="comma-separated harness names (module short "
                         "names, e.g. delta_pipeline,lineage_warmstart)")
    ap.add_argument("--strict", action="store_true",
                    help="a harness exception fails the run (CI mode) "
                         "instead of being reported as an ERROR row")
    ap.add_argument("--no-json", action="store_true",
                    help="skip the BENCH_*.json artifacts")
    args = ap.parse_args(argv)

    import benchmarks.record_overhead as b_rec
    import benchmarks.adaptive_ckpt as b_ada
    import benchmarks.background_mat as b_bg
    import benchmarks.storage_cost as b_st
    import benchmarks.replay_latency as b_rl
    import benchmarks.parallel_scaling as b_ps
    import benchmarks.roofline_summary as b_roof
    import benchmarks.delta_pipeline as b_dp
    import benchmarks.lineage_warmstart as b_lw
    import benchmarks.sharded_ckpt as b_sh
    import benchmarks.dist_record as b_dr
    import benchmarks.query_latency as b_ql

    mods = [b_bg, b_st, b_dp, b_sh, b_dr, b_lw, b_ql, b_rl, b_ps, b_rec,
            b_ada, b_roof]
    if args.only:
        wanted = {w.strip() for w in args.only.split(",") if w.strip()}
        known = {m.__name__.rsplit(".", 1)[-1] for m in mods}
        unknown = wanted - known
        if unknown:
            sys.exit(f"unknown harness(es) {sorted(unknown)}; "
                     f"known: {sorted(known)}")
        mods = [m for m in mods if m.__name__.rsplit(".", 1)[-1] in wanted]

    rows = Rows()
    print("bench,metric,value,note")
    failed = []
    for mod in mods:
        name = mod.__name__.rsplit(".", 1)[-1]
        start = len(rows.rows)
        t0 = time.time()
        error = None
        try:
            mod.run(rows)
        except Exception as e:  # noqa: BLE001 — report; --strict re-raises
            error = f"{type(e).__name__}: {e}"
            rows.add(mod.__name__, "ERROR", error)
            failed.append((name, e))
        wall = time.time() - t0
        rows.add(mod.__name__, "bench_wall_s", round(wall, 1))
        if not args.no_json:
            path = _emit_json(name, rows.rows[start:], wall, error)
            print(f"# wrote {os.path.relpath(path, REPO_ROOT)}",
                  file=sys.stderr)
    if failed and args.strict:
        for name, e in failed:
            print(f"STRICT: harness {name} failed: {e}", file=sys.stderr)
        raise failed[0][1]


if __name__ == '__main__':
    main()
