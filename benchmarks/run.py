"""Benchmark driver: one harness per paper table/figure. CSV to stdout."""
from __future__ import annotations

import sys
import time

from benchmarks.common import Rows


def main() -> None:
    import benchmarks.record_overhead as b_rec
    import benchmarks.adaptive_ckpt as b_ada
    import benchmarks.background_mat as b_bg
    import benchmarks.storage_cost as b_st
    import benchmarks.replay_latency as b_rl
    import benchmarks.parallel_scaling as b_ps
    import benchmarks.roofline_summary as b_roof
    import benchmarks.delta_pipeline as b_dp
    import benchmarks.lineage_warmstart as b_lw

    rows = Rows()
    print("bench,metric,value,note")
    for mod in (b_bg, b_st, b_dp, b_lw, b_rl, b_ps, b_rec, b_ada, b_roof):
        t0 = time.time()
        try:
            mod.run(rows)
        except Exception as e:  # noqa: BLE001 — report and continue
            rows.add(mod.__name__, "ERROR", f"{type(e).__name__}: {e}")
        rows.add(mod.__name__, "bench_wall_s", round(time.time() - t0, 1))


if __name__ == '__main__':
    main()
