"""Mesh-sharded record bandwidth + resharded restore correctness.

Measures the tentpole claim of the sharded checkpoint path: on an 8-device
(2, 4) mesh, every device runs the fused fingerprint+gather pass over its
OWN shard and ships bytes only to its host's store shard, so aggregate
record bandwidth scales with hosts instead of serializing through a
gather-to-one-host bottleneck.

    sharded_wall(ckpt)  = max over hosts (local stall + local shard write)
    baseline_wall(ckpt) = device_get(full tree) + flat sync pipeline submit

(Hosts run concurrently in production; this single-process simulation runs
them serially and reports the max — the honest production figure.) Gates:

  * aggregate sharded record bandwidth >= 4x the gather-to-one-host
    baseline on the 8-device mesh;
  * restores are BIT-IDENTICAL to the recorded tree when resharded onto a
    (4, 2) mesh, a (1, 8) mesh, and a plain unsharded host tree.

The measurement needs 8 simulated devices (XLA_FLAGS set before jax
imports), so ``run(rows)`` re-execs itself as a ``--child`` subprocess and
parses one JSON line back — same pattern the sharded tests use.
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys

from benchmarks.common import Rows

SMOKE = bool(os.environ.get("SMOKE"))
MESH_SHAPE = (2, 4)
RESHARD_SHAPES = ((4, 2), (1, 8))
SIDE = 512 if SMOKE else 2048         # three f32 (SIDE, SIDE) leaves
N_CKPTS = 3 if SMOKE else 5
MIN_SPEEDUP = 4.0


def _child() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.checkpoint import (CheckpointPipeline, CheckpointStore,
                                  restore_sharded_tree)

    tmp = "/tmp/bench_sharded_ckpt"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    store = CheckpointStore(os.path.join(tmp, "store"))

    devs = jax.devices()
    mesh = Mesh(np.array(devs).reshape(MESH_SHAPE), ("data", "model"))
    specs = {"win": P("data", "model"), "wout": P("model", "data"),
             "embed": P("data", "model"), "scale": P()}

    def make_state(step):
        # element-wise construction: identical bytes under any sharding.
        # sin(arange) is dense O(1) noise — zstd can't cheat, and the
        # multiplicative step is a RELATIVE change, so every element's bytes
        # really differ between checkpoints (an additive epsilon would be
        # absorbed by f32 rounding on large values, silently turning the
        # full-change workload into a near-empty delta)
        idx = jnp.arange(SIDE * SIDE, dtype=jnp.float32).reshape(SIDE, SIDE)
        noise = jnp.sin(idx)
        st = {"scale": jnp.float32(1.0 + 0.001 * (step + 3))}
        for i, k in enumerate(sorted(k for k in specs if k != "scale")):
            st[k] = noise * ((i + 1) * (1.0 + 0.001 * (step + 3)))
        return {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
                for k, v in st.items()}

    logical = 3 * SIDE * SIDE * 4

    # ---- sharded record: per-device fused pass -> per-host store shard ----
    pipe = CheckpointPipeline(store, async_stage=False, mesh=mesh)
    # two warm submits: the first compiles the no-previous fingerprint
    # variant, the second the with-previous (delta) variant — both must be
    # out of the measured window
    pipe.submit("warm@0.0", make_state(-2), block=True)
    pipe.submit("warm@1.0", make_state(-1), block=True)
    sh_walls = []
    for i in range(N_CKPTS):
        pipe.submit(f"train@{i}.0", make_state(i), block=True)
        stat = pipe.stats[-1]
        per_host = {h: stat["shard_stall_s"].get(h, 0.0) + w
                    for h, w in stat["shard_write_s"].items()}
        sh_walls.append(max(per_host.values()))
    n_shards = len(pipe.stats[-1]["shard_write_s"])
    pipe.close()

    # ---- baseline: gather the full tree to one host, flat sync write ----
    flat_store = CheckpointStore(os.path.join(tmp, "flat_store"))
    flat = CheckpointPipeline(flat_store, async_stage=False)
    for i, step in enumerate((-2, -1)):
        host_w = {k: np.asarray(jax.device_get(v))
                  for k, v in make_state(step).items()}
        flat.submit(f"warm@{i}.0", host_w, block=True)
    import time
    base_walls = []
    for i in range(N_CKPTS):
        state = make_state(i)
        t0 = time.perf_counter()
        host = {k: np.asarray(jax.device_get(v)) for k, v in state.items()}
        flat.submit(f"train@{i}.0", host, block=True)
        base_walls.append(time.perf_counter() - t0)
    flat.close()

    sh_bw = logical / (sum(sh_walls) / len(sh_walls))
    base_bw = logical / (sum(base_walls) / len(base_walls))

    # ---- resharded restores: bit-identical on every target layout ----
    last = f"train@{N_CKPTS - 1}.0"
    truth = {k: np.asarray(jax.device_get(v))
             for k, v in make_state(N_CKPTS - 1).items()}
    flat_like = {k: np.empty_like(v) for k, v in truth.items()}
    got = store.get_tree(last, like=flat_like)
    identical = {"unsharded": all(
        np.array_equal(got[k], truth[k]) for k in truth)}
    for shape in RESHARD_SHAPES:
        m2 = Mesh(np.array(devs).reshape(shape), ("data", "model"))
        out = restore_sharded_tree(store, last, m2)
        identical[f"{shape[0]}x{shape[1]}"] = all(
            np.array_equal(np.asarray(jax.device_get(out[f"['{k}']"])),
                           truth[k]) for k in truth)

    # ---- per-shard read calibration: the planner's shard_read_bps ----
    resolved = store.resolve_manifest(last)
    shard_read_bps = {}
    for hid, member in sorted(resolved["members_resolved"].items()):
        t0 = time.perf_counter()
        nbytes = 0
        for leaf in member["leaves"]:
            for h in leaf.get("chunks") or (leaf.get("delta") or {}).values():
                nbytes += len(store.get_chunk(h, shard=hid))
        dt = max(time.perf_counter() - t0, 1e-9)
        shard_read_bps[str(hid)] = nbytes / dt
    calib = dict(store.get_meta("store_calib") or {})
    calib["shard_read_bps"] = shard_read_bps
    store.put_meta("store_calib", calib)

    return {"logical_mb": logical / 2**20, "n_store_shards": n_shards,
            "sharded_wall_s": sum(sh_walls) / len(sh_walls),
            "baseline_wall_s": sum(base_walls) / len(base_walls),
            "sharded_bw_mbs": sh_bw / 2**20,
            "baseline_bw_mbs": base_bw / 2**20,
            "speedup": sh_bw / base_bw, "identical": identical,
            "shard_read_bps_spread":
                max(shard_read_bps.values()) / min(shard_read_bps.values())}


def run(rows: Rows):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        ["src", ".", env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded_ckpt", "--child"],
        env=env, capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded_ckpt child failed rc={proc.returncode}:"
                           f"\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    res = json.loads(proc.stdout.strip().splitlines()[-1])

    mesh_note = f"(2,4) mesh, {res['n_store_shards']} store shards, " \
                f"{res['logical_mb']:.0f} MiB state"
    rows.add("sharded_ckpt", "record_bw_sharded_mbs",
             round(res["sharded_bw_mbs"], 1), mesh_note)
    rows.add("sharded_ckpt", "record_bw_gather_mbs",
             round(res["baseline_bw_mbs"], 1), "gather-to-one-host baseline")
    rows.add("sharded_ckpt", "record_bw_speedup", round(res["speedup"], 2),
             f"gate >= {MIN_SPEEDUP}x")
    for layout, ok in sorted(res["identical"].items()):
        rows.add("sharded_ckpt", f"restore_identical_{layout}", bool(ok),
                 "bit-identical resharded restore")
    rows.add("sharded_ckpt", "shard_read_bps_spread",
             round(res["shard_read_bps_spread"], 2),
             "max/min learned per-shard read rate")

    assert res["speedup"] >= MIN_SPEEDUP, \
        f"sharded record bandwidth {res['speedup']:.2f}x < {MIN_SPEEDUP}x"
    assert all(res["identical"].values()), \
        f"resharded restore not bit-identical: {res['identical']}"


if __name__ == "__main__":
    if "--child" in sys.argv:
        print(json.dumps(_child()))
    else:
        run(Rows())
