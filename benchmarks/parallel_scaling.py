"""Paper Figs. 10/13/14: hindsight parallelism scale-out and marginal cost.

This container has ONE core, so wall-clock can't show multi-worker speedup
directly. We measure the two quantities that determine it and validate the
paper's model:
  * per-worker measured epoch times C and restore times R (real),
  * per-worker work assignment from the real partitioner,
then parallel wall = max over workers of (|init_restores|*R + |work|*C) —
the coordination-free bound the paper's Fig. 13 hits (workers never talk).
The subprocess path itself is exercised in tests/test_system.py.
"""
from __future__ import annotations

import shutil
import time

import repro.flor as flor
from benchmarks.common import (P3_2XLARGE_USD_HR, P3_8XLARGE_USD_HR, Rows,
                               make_runner, train_like)
from repro.core.generator import partition

EPOCHS = 16


def run(rows: Rows, tmp="/tmp/bench_scaling"):
    cfg, kw = train_like()
    state0, run_epoch = make_runner(cfg, **kw)
    run_dir = f"{tmp}/run"
    shutil.rmtree(run_dir, ignore_errors=True)

    # record, measuring epoch compute time C
    flor.init(run_dir, mode="record", adaptive=False)
    state = state0
    t0 = time.perf_counter()
    for e in flor.generator(range(EPOCHS)):
        if flor.skipblock.step_into("train"):
            state, _ = run_epoch(state, e)
        state = flor.skipblock.end("train", state)
    wall_record = time.perf_counter() - t0
    ctx = flor.get_context()
    C = ctx.controller.blocks["train"].C.value
    flor.finish()

    # measure restore time R (real restore from store)
    flor.init(run_dir, mode="replay", probed=set())
    t0 = time.perf_counter()
    st = state0
    ctx = flor.get_context()
    ctx.begin_epoch(0)
    if not flor.skipblock.step_into("train"):
        st = flor.skipblock.end("train", st)
    R = time.perf_counter() - t0
    flor.finish()

    serial = EPOCHS * C
    rows.add("parallel_scaling(fig13)", "epoch_compute_s", round(C, 3))
    rows.add("parallel_scaling(fig13)", "restore_s", round(R, 4))
    rows.add("parallel_scaling(fig13)", "serial_replay_s", round(serial, 2))
    for g in (1, 2, 4, 8, 16):
        walls = []
        for pid in range(g):
            before, mine = partition(list(range(EPOCHS)), g, pid)
            walls.append(len(before) * R + len(mine) * C)   # strong init
        wall = max(walls)
        speedup = serial / wall
        ideal = g if EPOCHS % g == 0 else EPOCHS / -(-EPOCHS // g)
        rows.add("parallel_scaling(fig13)", f"g{g}_speedup",
                 round(speedup, 2), f"ideal {round(ideal, 2)}")
        # fig14: marginal cost of parallelism (4 workers per machine)
        machines = -(-g // 4)
        usd = machines * (P3_8XLARGE_USD_HR / 3600) * wall if g > 1 else \
            (P3_2XLARGE_USD_HR / 3600) * wall
        rows.add("parallel_cost(fig14)", f"g{g}_usd",
                 round(usd, 6), f"{machines} machine(s)")


if __name__ == "__main__":
    run(Rows())
