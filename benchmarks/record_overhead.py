"""Paper Fig. 11 (record overhead vs vanilla, target ~1.47%) + the
background-logging overhead model (paper task (i)).

The logging section is the PR-5 acceptance gate: with ``async_log=True``
``flor.log`` is a capture+enqueue, so the STEP-PATH time spent in logging
must be at least 2x lower than the synchronous serialize+write path on a
logging-heavy workload — while ``flor.log_records`` stays bit-identical
between the two modes, and stays bit-identical after a torn-segment
recovery (a background writer killed mid-write).

Run standalone (``SMOKE=1 PYTHONPATH=src:. python -m
benchmarks.record_overhead``) it executes the logging section with hard
asserts — CI's record-overhead smoke step; SMOKE only shrinks sizes.
"""
from __future__ import annotations

import json
import os
import shutil
import statistics
import time

import jax
import jax.numpy as jnp

import repro.flor as flor
from benchmarks.common import Rows, finetune_like, make_runner, train_like

EPOCHS = 8

SMOKE = bool(os.environ.get("SMOKE"))
# logging-heavy config: per-step array probes make serialization the cost
LOG_EPOCHS = 4 if SMOKE else 8
LOG_STEPS = 20 if SMOKE else 60
LOG_ELEMS = 16 * 1024 if SMOKE else 64 * 1024        # f32 per logged array
TRIALS = 3 if SMOKE else 5


def _timed_trials(fn, n=TRIALS):
    """Median over n trials plus the relative spread ((max - min) / median).

    Overhead percentages compare two medians, so a single preempted trial
    no longer flips the sign of the reported overhead the way min-of-2
    did; the spread lands in the report so noisy runs are visible instead
    of silently trusted."""
    ts = sorted(fn() for _ in range(n))
    med = statistics.median(ts)
    return med, (ts[-1] - ts[0]) / max(med, 1e-9) * 100.0


def _vanilla(state, run_epoch):
    t0 = time.perf_counter()
    for e in range(EPOCHS):
        state, _ = run_epoch(state, e)
    return time.perf_counter() - t0


def _flor_record(state, run_epoch, run_dir, adaptive=True):
    shutil.rmtree(run_dir, ignore_errors=True)
    with flor.Session(run_dir, mode="record",
                      record=flor.RecordSpec(adaptive=adaptive)) as sess:
        t0 = time.perf_counter()
        with sess.checkpointing(state=state) as ckpt:
            for e in sess.loop("epochs", range(EPOCHS)):
                for _ in sess.loop("train", range(1)):
                    ckpt.state, m = run_epoch(ckpt.state, e)
                flor.log("loss", m["loss"])
        wall = time.perf_counter() - t0
    return wall


# ------------------------------------------------- background logging -------
def _logging_run(run_dir: str, async_log: bool) -> float:
    """A logging-heavy record run; returns the STEP-PATH seconds spent
    inside flor.log (the overhead the paper's task (i) bounds). Identical
    values are logged in both modes; spill is disabled so both serialize
    the full arrays."""
    shutil.rmtree(run_dir, ignore_errors=True)
    base = jnp.arange(LOG_ELEMS, dtype=jnp.float32)
    jax.block_until_ready(base)
    t_log = 0.0
    with flor.Session(run_dir, mode="record",
                      record=flor.RecordSpec(adaptive=False,
                                             async_log=async_log,
                                             log_spill_bytes=0)) as sess:
        for e in sess.loop("epochs", range(LOG_EPOCHS)):
            for s in range(LOG_STEPS):
                v = base + jnp.float32(e * LOG_STEPS + s)
                jax.block_until_ready(v)              # value ready pre-clock
                t0 = time.perf_counter()
                flor.log("hist", v)
                flor.log("step_scalar", e * LOG_STEPS + s)
                t_log += time.perf_counter() - t0
    return t_log


def _payload(run_dir: str):
    rows = flor.FingerprintLog.read(
        os.path.join(run_dir, "logs", "record.jsonl"))
    return [(r["epoch"], r["seq"], r["key"], json.dumps(r["value"]))
            for r in rows]


def _tear_last_segment(run_dir: str):
    from repro.logging import list_segments
    segs = list_segments(os.path.join(run_dir, "logs", "record.jsonl"))
    with open(segs[-1][1], "a") as f:
        f.write('{"epoch": 0, "seq": 424242, "key": "torn", "val')


def run_logging(rows: Rows, tmp="/tmp/bench_record_overhead"):
    """Async vs sync flor.log on the step path + bit-identity asserts."""
    run_async = f"{tmp}/logging_async"
    run_sync = f"{tmp}/logging_sync"
    t_async, sp_a = _timed_trials(
        lambda: _logging_run(run_async, async_log=True))
    t_sync, sp_s = _timed_trials(
        lambda: _logging_run(run_sync, async_log=False))
    n = LOG_EPOCHS * LOG_STEPS
    rows.add("record_overhead(logging)", "trial_spread_pct",
             round(max(sp_a, sp_s), 1),
             f"(max-min)/median over {TRIALS} trials, worst mode")
    rows.add("record_overhead(logging)", "sync_steppath_ms_per_step",
             round(t_sync / n * 1e3, 4))
    rows.add("record_overhead(logging)", "async_steppath_ms_per_step",
             round(t_async / n * 1e3, 4))
    speedup = t_sync / max(t_async, 1e-9)
    rows.add("record_overhead(logging)", "steppath_speedup",
             round(speedup, 2), "acceptance: >= 2x")
    assert t_async <= 0.5 * t_sync, \
        f"async logging step-path time {t_async:.4f}s not <= 0.5x " \
        f"sync {t_sync:.4f}s"
    pa, ps = _payload(run_async), _payload(run_sync)
    assert pa == ps, "log_records diverge between async and sync modes"
    # torn-segment recovery: kill-mid-write leaves a half line; the reader
    # must still serve the identical rows
    _tear_last_segment(run_async)
    assert _payload(run_async) == ps, \
        "log_records changed across torn-segment recovery"
    rows.add("record_overhead(logging)", "bit_identical", 1,
             "async == sync == torn-recovered")


def run(rows: Rows, tmp="/tmp/bench_record_overhead"):
    for name, (cfg, kw) in (("train_like", train_like()),
                            ("finetune_like", finetune_like())):
        state0, run_epoch = make_runner(cfg, **kw)
        tv, sp_v = _timed_trials(lambda: _vanilla(state0, run_epoch))
        tf, sp_f = _timed_trials(
            lambda: _flor_record(state0, run_epoch, f"{tmp}/{name}"))
        ovh = (tf - tv) / tv * 100
        rows.add("record_overhead(fig11)", f"{name}_vanilla_s", round(tv, 3))
        rows.add("record_overhead(fig11)", f"{name}_flor_s", round(tf, 3))
        rows.add("record_overhead(fig11)", f"{name}_overhead_pct",
                 round(ovh, 2), "paper avg 1.47%")
        rows.add("record_overhead(fig11)", f"{name}_trial_spread_pct",
                 round(max(sp_v, sp_f), 1),
                 f"(max-min)/median over {TRIALS} trials; an overhead "
                 "smaller than the spread is noise")
    run_logging(rows, tmp=tmp)


if __name__ == "__main__":
    rows = Rows()
    if SMOKE:
        run_logging(rows)          # CI smoke: logging acceptance gate only
    else:
        run(rows)
