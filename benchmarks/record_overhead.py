"""Paper Fig. 11: record overhead vs vanilla execution (target: ~1.47%)."""
from __future__ import annotations

import shutil
import time

import jax

import repro.flor as flor
from benchmarks.common import Rows, finetune_like, make_runner, train_like

EPOCHS = 8


def _vanilla(state, run_epoch):
    t0 = time.perf_counter()
    for e in range(EPOCHS):
        state, _ = run_epoch(state, e)
    return time.perf_counter() - t0


def _flor_record(state, run_epoch, run_dir, adaptive=True):
    shutil.rmtree(run_dir, ignore_errors=True)
    flor.init(run_dir, mode="record", adaptive=adaptive)
    t0 = time.perf_counter()
    for e in flor.generator(range(EPOCHS)):
        if flor.skipblock.step_into("train"):
            state, m = run_epoch(state, e)
            flor.log("loss", m["loss"])
        state = flor.skipblock.end("train", state)
    wall = time.perf_counter() - t0
    flor.finish()
    return wall


def run(rows: Rows, tmp="/tmp/bench_record_overhead"):
    for name, (cfg, kw) in (("train_like", train_like()),
                            ("finetune_like", finetune_like())):
        state0, run_epoch = make_runner(cfg, **kw)
        tv = min(_vanilla(state0, run_epoch) for _ in range(2))
        tf = min(_flor_record(state0, run_epoch, f"{tmp}/{name}")
                 for _ in range(2))
        ovh = (tf - tv) / tv * 100
        rows.add("record_overhead(fig11)", f"{name}_vanilla_s", round(tv, 3))
        rows.add("record_overhead(fig11)", f"{name}_flor_s", round(tf, 3))
        rows.add("record_overhead(fig11)", f"{name}_overhead_pct",
                 round(ovh, 2), "paper avg 1.47%")


if __name__ == "__main__":
    run(Rows())
