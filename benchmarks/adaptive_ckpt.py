"""Paper Fig. 7: adaptive checkpointing caps overhead at epsilon.

The fine-tune-like workload (checkpoint cost comparable to epoch compute)
is where adaptivity matters: with it disabled the overhead blows past the
tolerance (paper: 91% on RTE); enabled, it must stay under epsilon.
"""
from __future__ import annotations

import shutil
import time

import repro.flor as flor
from benchmarks.common import Rows, finetune_like, make_runner

EPOCHS = 12
EPS = 1.0 / 15


def _run(state, run_epoch, run_dir, adaptive, sync):
    shutil.rmtree(run_dir, ignore_errors=True)
    flor.init(run_dir, mode="record", adaptive=adaptive, epsilon=EPS,
              async_materialize=not sync)
    t0 = time.perf_counter()
    for e in flor.generator(range(EPOCHS)):
        if flor.skipblock.step_into("train"):
            state, m = run_epoch(state, e)
        state = flor.skipblock.end("train", state)
    wall = time.perf_counter() - t0
    ctx = flor.get_context()
    snap = ctx.controller.snapshot()
    flor.finish()
    k = snap["blocks"]["train"]["k"]
    return wall, k


def run(rows: Rows, tmp="/tmp/bench_adaptive"):
    cfg, kw = finetune_like()
    state0, run_epoch = make_runner(cfg, **kw)
    t0 = time.perf_counter()
    for e in range(EPOCHS):
        state, _ = run_epoch(state0, e)
    tv = time.perf_counter() - t0

    # adaptivity disabled + synchronous materialization = worst case
    tw, kw_ = _run(state0, run_epoch, f"{tmp}/off", adaptive=False, sync=True)
    ta, ka = _run(state0, run_epoch, f"{tmp}/on", adaptive=True, sync=True)
    rows.add("adaptive_ckpt(fig7)", "vanilla_s", round(tv, 3))
    rows.add("adaptive_ckpt(fig7)", "adaptive_off_overhead_pct",
             round((tw - tv) / tv * 100, 1), f"ckpts={kw_}/{EPOCHS}")
    rows.add("adaptive_ckpt(fig7)", "adaptive_on_overhead_pct",
             round((ta - tv) / tv * 100, 1), f"ckpts={ka}/{EPOCHS}")
    rows.add("adaptive_ckpt(fig7)", "epsilon_pct", round(EPS * 100, 2),
             "user tolerance")
    rows.add("adaptive_ckpt(fig7)", "sparse_checkpointing",
             int(ka < EPOCHS), "1 = controller went periodic")


if __name__ == "__main__":
    run(Rows())
