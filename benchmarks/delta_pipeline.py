"""Delta-aware checkpoint pipeline on a frozen-majority workload.

The lean-checkpointing claim, measured: on a fine-tune-shaped state (frozen
backbone, hot head + optimizer slots) per-checkpoint device->host traffic
must drop by roughly the frozen fraction versus the full-transfer path, and
a delta-restored tree must be bit-identical to a full-manifest restore.
"""
from __future__ import annotations

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, timed
from repro.checkpoint import CheckpointPipeline, CheckpointStore

# SMOKE=1: CI-sized run — same assertions (bit-identical delta restores),
# fewer checkpoints
CKPTS = 6 if os.environ.get("SMOKE") else 20
FULL_EVERY = 4 if os.environ.get("SMOKE") else 8


def _finetune_state(hot_fraction: float = 0.04):
    """Frozen backbone + hot head sized so head bytes ~= hot_fraction."""
    k = jax.random.PRNGKey(0)
    backbone = {
        "embed": jax.random.normal(k, (1 << 20,)),            # 4 MB
        "layers": jax.random.normal(k, (1 << 21,)),           # 8 MB
    }
    total = sum(int(x.nbytes) for x in backbone.values())
    hot_n = max(1024, int(total * hot_fraction / (1 - hot_fraction)) // 8)
    head = jax.random.normal(k, (hot_n,))
    return {"backbone": backbone, "head": head,
            "opt": {"head_mu": jnp.zeros((hot_n,))}}


def _step(state, i: float):
    """Fine-tune-shaped update: backbone untouched, head + slot move."""
    return {"backbone": state["backbone"],
            "head": state["head"] + 0.1 * i,
            "opt": {"head_mu": state["opt"]["head_mu"] + 0.01 * i}}


def run(rows: Rows, tmp="/tmp/bench_delta_pipeline"):
    shutil.rmtree(tmp, ignore_errors=True)
    from repro.utils.pytree import tree_bytes
    state = _finetune_state()
    logical = tree_bytes(state)
    hot = int(state["head"].nbytes + state["opt"]["head_mu"].nbytes)
    frozen_frac = 1 - hot / logical

    # warm the fingerprint/gather jit cache (benchmarks/common convention:
    # measurements exclude one-time compilation)
    warm = CheckpointPipeline(CheckpointStore(f"{tmp}/warm"),
                              full_every=FULL_EVERY, async_stage=False)
    warm.submit("w0", state, scope="train")
    warm.submit("w1", _step(state, 1.0), scope="train")
    warm.close()

    # --- delta path --------------------------------------------------------
    dstore = CheckpointStore(f"{tmp}/delta")
    pipe = CheckpointPipeline(dstore, full_every=FULL_EVERY)
    submit_walls = []

    def _delta_run():
        st = state
        for i in range(CKPTS):
            st = _step(st, float(i))
            _, dt = timed(pipe.submit, f"ck{i}", st, scope="train")
            submit_walls.append(dt)
        pipe.drain()
        return st
    final_state, delta_wall = timed(_delta_run)
    delta_stats = [st for st in pipe.stats if st["kind"] == "delta"]
    pipe.close()
    mean_transfer = float(np.mean([st["transferred_bytes"]
                                   for st in delta_stats]))

    # --- full-transfer baseline (classic whole-tree path) ------------------
    fstore = CheckpointStore(f"{tmp}/full")
    full_walls = []

    def _full_run():
        st = state
        for i in range(CKPTS):
            st = _step(st, float(i))

            def _materialize(t=st, i=i):
                host = jax.tree_util.tree_map(
                    lambda x: np.asarray(jax.device_get(x)), t)
                fstore.put_tree(f"ck{i}", host)
            _, dt = timed(_materialize)
            full_walls.append(dt)
    _, full_wall = timed(_full_run)

    # --- bit-identical acceptance ------------------------------------------
    fstore.put_tree("truth", jax.tree_util.tree_map(
        lambda x: np.asarray(jax.device_get(x)), final_state))
    via_delta = dstore.get_tree(f"ck{CKPTS - 1}", like=final_state)
    via_full = fstore.get_tree("truth", like=final_state)
    identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        and str(np.asarray(a).dtype) == str(np.asarray(b).dtype)
        for a, b in zip(jax.tree_util.tree_leaves(via_delta),
                        jax.tree_util.tree_leaves(via_full)))

    rows.add("delta_pipeline", "logical_mb", round(logical / 2**20, 2),
             "per-checkpoint state size")
    rows.add("delta_pipeline", "frozen_fraction", round(frozen_frac, 4))
    rows.add("delta_pipeline", "delta_transfer_mb",
             round(mean_transfer / 2**20, 3),
             "mean device->host per delta ckpt")
    rows.add("delta_pipeline", "transfer_fraction",
             round(mean_transfer / logical, 4),
             f"expected ~{1 - frozen_frac:.4f} (hot fraction)")
    rows.add("delta_pipeline", "transfer_savings_x",
             round(logical / max(mean_transfer, 1), 1),
             "vs full-transfer path")
    rows.add("delta_pipeline", "record_wall_s_delta", round(delta_wall, 3),
             f"{CKPTS} ckpts, async writer")
    rows.add("delta_pipeline", "record_wall_s_full", round(full_wall, 3),
             f"{CKPTS} ckpts, sync whole-tree")
    rows.add("delta_pipeline", "per_ckpt_ms_delta_steady",
             round(float(np.median(submit_walls[FULL_EVERY:])) * 1e3, 1),
             "median submit stall, past first full")
    rows.add("delta_pipeline", "per_ckpt_ms_full",
             round(float(np.median(full_walls)) * 1e3, 1),
             "median whole-tree materialize")
    rows.add("delta_pipeline", "delta_restore_bit_identical", identical,
             "vs full-manifest restore")
    assert identical, "delta restore diverged from full-manifest restore"


if __name__ == "__main__":
    run(Rows())
