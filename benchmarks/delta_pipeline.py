"""Delta-aware checkpoint pipeline on a frozen-majority workload.

The lean-checkpointing claim, measured: on a fine-tune-shaped state (frozen
backbone, hot head + optimizer slots) per-checkpoint device->host traffic
must drop by roughly the frozen fraction versus the full-transfer path, and
a delta-restored tree must be bit-identical to a full-manifest restore.
"""
from __future__ import annotations

import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, timed
from repro.checkpoint import CheckpointPipeline, CheckpointStore

# SMOKE=1: CI-sized run — same assertions (bit-identical delta restores),
# fewer checkpoints
CKPTS = 6 if os.environ.get("SMOKE") else 20
FULL_EVERY = 4 if os.environ.get("SMOKE") else 8
# inter-checkpoint gap standing in for device-bound step compute: during a
# real epoch the host is idle while the accelerator runs, which is exactly
# the window overlap mode's writer thread finalizes in. Back-to-back
# submits would instead measure writer-queue backpressure for BOTH paths.
# Applied identically to the delta and fused runs (and subtracted from the
# reported record walls), so only the foreground stall differs.
STEP_GAP_S = 0.05


def _finetune_state(hot_fraction: float = 0.04):
    """Frozen backbone + hot head sized so head bytes ~= hot_fraction."""
    k = jax.random.PRNGKey(0)
    backbone = {
        "embed": jax.random.normal(k, (1 << 20,)),            # 4 MB
        "layers": jax.random.normal(k, (1 << 21,)),           # 8 MB
    }
    total = sum(int(x.nbytes) for x in backbone.values())
    hot_n = max(1024, int(total * hot_fraction / (1 - hot_fraction)) // 8)
    head = jax.random.normal(k, (hot_n,))
    return {"backbone": backbone, "head": head,
            "opt": {"head_mu": jnp.zeros((hot_n,))}}


def _step(state, i: float):
    """Fine-tune-shaped update: backbone untouched, head + slot move."""
    return {"backbone": state["backbone"],
            "head": state["head"] + 0.1 * i,
            "opt": {"head_mu": state["opt"]["head_mu"] + 0.01 * i}}


def run(rows: Rows, tmp="/tmp/bench_delta_pipeline"):
    shutil.rmtree(tmp, ignore_errors=True)
    from repro.utils.pytree import tree_bytes
    state = _finetune_state()
    logical = tree_bytes(state)
    hot = int(state["head"].nbytes + state["opt"]["head_mu"].nbytes)
    frozen_frac = 1 - hot / logical

    # warm the fingerprint/gather jit cache (benchmarks/common convention:
    # measurements exclude one-time compilation)
    warm = CheckpointPipeline(CheckpointStore(f"{tmp}/warm"),
                              full_every=FULL_EVERY, async_stage=False)
    warm.submit("w0", state, scope="train")
    warm.submit("w1", _step(state, 1.0), scope="train")
    warm.close()

    # --- delta path --------------------------------------------------------
    dstore = CheckpointStore(f"{tmp}/delta")
    pipe = CheckpointPipeline(dstore, full_every=FULL_EVERY)
    submit_walls = []

    def _delta_run():
        st = state
        for i in range(CKPTS):
            st = _step(st, float(i))
            _, dt = timed(pipe.submit, f"ck{i}", st, scope="train")
            submit_walls.append(dt)
            time.sleep(STEP_GAP_S)        # device-bound step stand-in
        pipe.drain()
        return st
    final_state, delta_wall = timed(_delta_run)
    delta_wall -= CKPTS * STEP_GAP_S
    delta_stats = [st for st in pipe.stats if st["kind"] == "delta"]
    pipe.close()
    mean_transfer = float(np.mean([st["transferred_bytes"]
                                   for st in delta_stats]))

    # --- full-transfer baseline (classic whole-tree path) ------------------
    fstore = CheckpointStore(f"{tmp}/full")
    full_walls = []

    def _full_run():
        st = state
        for i in range(CKPTS):
            st = _step(st, float(i))

            def _materialize(t=st, i=i):
                host = jax.tree_util.tree_map(
                    lambda x: np.asarray(jax.device_get(x)), t)
                fstore.put_tree(f"ck{i}", host)
            _, dt = timed(_materialize)
            full_walls.append(dt)
    _, full_wall = timed(_full_run)

    # --- fused fast path: overlapped fused pass + quantized slot -----------
    # same workload through the kernel-fused path: the optimizer slot is
    # opted into wire-format q8 (lossy, bounded), params/backbone stay
    # exact, and the fused fingerprint+mask pass overlaps the step — the
    # foreground pays dispatch only, the writer thread syncs/gathers/encodes
    def _fused_attempt(tag):
        store = CheckpointStore(f"{tmp}/fused{tag}")
        pipe_q = CheckpointPipeline(store, full_every=FULL_EVERY,
                                    quantize_slots=("head_mu",), overlap=True)
        walls = []

        def _loop():
            st = state
            for i in range(CKPTS):
                st = _step(st, float(i))
                _, dt = timed(pipe_q.submit, f"ck{i}", st, scope="train")
                walls.append(dt)
                time.sleep(STEP_GAP_S)    # same gap as the delta run
            pipe_q.drain()
            return st
        final, wall = timed(_loop)
        stats = [st for st in pipe_q.stats if st["kind"] == "delta"]
        pipe_q.close()
        return store, final, wall - CKPTS * STEP_GAP_S, walls, stats

    fg_delta_ms = float(np.median(submit_walls[FULL_EVERY:])) * 1e3
    # the overlap win only shows on an otherwise-idle host (the writer
    # finalizes inside the step gap); a noisy neighbor can inflate one
    # timing attempt, so the gate gets a single fresh-store retry
    for attempt in range(2):
        (qstore, fused_final, fused_wall,
         fused_submit_walls, fused_stats) = _fused_attempt(attempt)
        fg_fused_ms = float(np.median(fused_submit_walls[FULL_EVERY:])) * 1e3
        fg_reduction = fg_delta_ms / max(fg_fused_ms, 1e-6)
        if fg_reduction >= 1.5:
            break
        print(f"# fused attempt {attempt}: foreground reduction "
              f"{fg_reduction:.2f}x < 1.5x — "
              f"{'retrying once' if attempt == 0 else 'keeping result'}")
    fused_transfer = float(np.mean([st["transferred_bytes"]
                                    for st in fused_stats]))
    # the two paths see identical change sets (same deterministic step), so
    # the transfer difference is exactly the q8 shrink on the mu slot
    mu_raw = int(state["opt"]["head_mu"].nbytes)
    mu_q8 = mu_raw - (mean_transfer - fused_transfer)
    q8_shrink = mu_raw / max(mu_q8, 1.0)

    # --- bit-identical acceptance ------------------------------------------
    fstore.put_tree("truth", jax.tree_util.tree_map(
        lambda x: np.asarray(jax.device_get(x)), final_state))
    via_delta = dstore.get_tree(f"ck{CKPTS - 1}", like=final_state)
    via_full = fstore.get_tree("truth", like=final_state)
    identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        and str(np.asarray(a).dtype) == str(np.asarray(b).dtype)
        for a, b in zip(jax.tree_util.tree_leaves(via_delta),
                        jax.tree_util.tree_leaves(via_full)))
    # fused-path acceptance: exact slots bit-identical through the fused
    # kernels, quantized slot error within the blockwise-q8 bound
    via_fused = qstore.get_tree(f"ck{CKPTS - 1}", like=fused_final)
    mu_true = np.asarray(jax.device_get(fused_final["opt"]["head_mu"]))
    mu_got = np.asarray(via_fused["opt"]["head_mu"])
    mu_err_ok = bool(np.max(np.abs(mu_got - mu_true))
                     <= max(np.max(np.abs(mu_true)), 1e-12) / 126)
    fused_exact = all(
        np.array_equal(np.asarray(jax.device_get(a)), np.asarray(b))
        for a, b in (
            (fused_final["backbone"]["embed"], via_fused["backbone"]["embed"]),
            (fused_final["backbone"]["layers"],
             via_fused["backbone"]["layers"]),
            (fused_final["head"], via_fused["head"])))

    rows.add("delta_pipeline", "logical_mb", round(logical / 2**20, 2),
             "per-checkpoint state size")
    rows.add("delta_pipeline", "frozen_fraction", round(frozen_frac, 4))
    rows.add("delta_pipeline", "delta_transfer_mb",
             round(mean_transfer / 2**20, 3),
             "mean device->host per delta ckpt")
    rows.add("delta_pipeline", "transfer_fraction",
             round(mean_transfer / logical, 4),
             f"expected ~{1 - frozen_frac:.4f} (hot fraction)")
    rows.add("delta_pipeline", "transfer_savings_x",
             round(logical / max(mean_transfer, 1), 1),
             "vs full-transfer path")
    rows.add("delta_pipeline", "record_wall_s_delta", round(delta_wall, 3),
             f"{CKPTS} ckpts, async writer")
    rows.add("delta_pipeline", "record_wall_s_full", round(full_wall, 3),
             f"{CKPTS} ckpts, sync whole-tree")
    rows.add("delta_pipeline", "per_ckpt_ms_delta_steady",
             round(float(np.median(submit_walls[FULL_EVERY:])) * 1e3, 1),
             "median submit stall, past first full")
    rows.add("delta_pipeline", "per_ckpt_ms_full",
             round(float(np.median(full_walls)) * 1e3, 1),
             "median whole-tree materialize")
    rows.add("delta_pipeline", "delta_restore_bit_identical", identical,
             "vs full-manifest restore")
    rows.add("delta_pipeline", "fused_transfer_mb",
             round(fused_transfer / 2**20, 3),
             "mean device->host per fused+q8 delta ckpt")
    rows.add("delta_pipeline", "q8_slot_shrink_x", round(q8_shrink, 2),
             "quantized slot bytes vs raw (expect ~3.9x for f32)")
    rows.add("delta_pipeline", "per_ckpt_ms_fused_steady",
             round(fg_fused_ms, 2),
             "median foreground stall, overlapped fused pass")
    rows.add("delta_pipeline", "foreground_reduction_x",
             round(fg_reduction, 1),
             "separate sync delta vs fused+overlap foreground")
    rows.add("delta_pipeline", "fused_exact_bit_identical", fused_exact,
             "non-quantized slots through the fused path")
    rows.add("delta_pipeline", "fused_q8_err_bounded", mu_err_ok,
             "quantized slot within blockwise-q8 bound")
    assert identical, "delta restore diverged from full-manifest restore"
    assert fused_exact, "fused path broke a bit-identical (exact) slot"
    assert mu_err_ok, "fused q8 slot exceeded the quantization error bound"
    assert q8_shrink >= 3.0, \
        f"q8 slot shrink {q8_shrink:.2f}x < 3x (expected ~3.9x for f32)"
    assert fg_reduction >= 1.5, \
        f"fused+overlap foreground reduction {fg_reduction:.2f}x < 1.5x " \
        f"(fused {fg_fused_ms:.2f}ms vs separate {fg_delta_ms:.2f}ms)"


if __name__ == "__main__":
    run(Rows())
