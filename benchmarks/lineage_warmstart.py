"""Cross-run warm-start on a frozen-majority lineage (run B fine-tunes run
A's final checkpoint in a SHARED store).

The multiversion lean-checkpointing claim, measured: a derived run's FIRST
checkpoint must cost what changed since its ancestor, not model size —
transfer fraction ~= hot fraction versus 1.0 for a cold store. Replay of
run B restores bit-identically THROUGH run A's chunks, and registry gc
after dropping run A reclaims only chunks unreachable from run B.

Set SMOKE=1 for the CI-sized variant (same assertions, smaller state).
"""
from __future__ import annotations

import os
import shutil

import jax
import numpy as np

from benchmarks.common import Rows, timed

SMOKE = bool(os.environ.get("SMOKE"))
SCALE = 1 if SMOKE else 8          # backbone size multiplier
A_CKPTS = 4 if SMOKE else 10
B_CKPTS = 3 if SMOKE else 10
FULL_EVERY = 2 if SMOKE else 4
HOT_FRACTION = 0.04


def _finetune_state(hot_fraction: float = HOT_FRACTION):
    """Frozen backbone + hot head sized so head bytes ~= hot_fraction."""
    k = jax.random.PRNGKey(0)
    backbone = {
        "embed": jax.random.normal(k, (SCALE << 17,)),     # 4 MB at SCALE=8
        "layers": jax.random.normal(k, (SCALE << 18,)),    # 8 MB at SCALE=8
    }
    total = sum(int(x.nbytes) for x in backbone.values())
    hot_n = max(1024, int(total * hot_fraction / (1 - hot_fraction)) // 8)
    head = jax.random.normal(k, (hot_n,))
    return {"backbone": backbone, "head": head,
            "opt": {"head_mu": np.zeros((hot_n,), np.float32)}}


def _step(state, i: float):
    """Fine-tune-shaped update: backbone untouched, head + slot move."""
    return {"backbone": state["backbone"],
            "head": np.asarray(state["head"]) + 0.1 * i,
            "opt": {"head_mu": np.asarray(state["opt"]["head_mu"]) + 0.01 * i}}


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        and str(np.asarray(x).dtype) == str(np.asarray(y).dtype)
        for x, y in zip(la, lb))


def run(rows: Rows, tmp="/tmp/bench_lineage_warmstart"):
    import repro.flor as flor
    from repro.checkpoint import CheckpointStore, RunRegistry
    from repro.utils.pytree import tree_bytes

    shutil.rmtree(tmp, ignore_errors=True)
    store_root = os.path.join(tmp, "store")
    state = _finetune_state()
    logical = tree_bytes(state)
    hot = int(np.asarray(state["head"]).nbytes
              + np.asarray(state["opt"]["head_mu"]).nbytes)
    hot_frac = hot / logical

    # --- run A: the base recording ----------------------------------------
    flor.init(os.path.join(tmp, "runA"), mode="record", adaptive=False,
              store_root=store_root, run_id="A",
              full_manifest_every=FULL_EVERY)
    ctx = flor.get_context()
    st = state
    for i in range(A_CKPTS):
        st = _step(st, float(i))
        ctx.submit_checkpoint("train", f"train@{i}.0", st, meta={})
    flor.finish()
    final_a = st

    # --- run B: warm-started derived run ----------------------------------
    flor.init(os.path.join(tmp, "runB"), mode="record", adaptive=False,
              store_root=store_root, run_id="B", parent_run="A",
              full_manifest_every=max(FULL_EVERY, B_CKPTS + 1))
    ctx = flor.get_context()
    (warm, warm_s) = timed(flor.warm_start, "train", like=state)
    assert _leaves_equal(warm, final_a), "warm start != parent final state"
    st = warm
    first_stat = None
    for i in range(B_CKPTS):
        st = _step(st, float(A_CKPTS + i))
        ctx.submit_checkpoint("train", f"train@{i}.0", st, meta={})
        if first_stat is None:
            ctx.pipeline.drain()
            first_stat = ctx.pipeline.stats[0]
    flor.finish()
    final_b = st
    warm_frac = first_stat["transferred_bytes"] / logical

    # --- cold baseline: same derived run, fresh private store -------------
    flor.init(os.path.join(tmp, "runCold"), mode="record", adaptive=False,
              full_manifest_every=max(FULL_EVERY, B_CKPTS + 1))
    ctx = flor.get_context()
    st = {k: v for k, v in final_a.items()}
    st = _step(st, float(A_CKPTS))
    ctx.submit_checkpoint("train", "train@0.0", st, meta={})
    ctx.pipeline.drain()
    cold_frac = ctx.pipeline.stats[0]["transferred_bytes"] / logical
    flor.finish()

    # --- replay of run B restores through run A's chunks -------------------
    flor.init(os.path.join(tmp, "runB"), mode="replay")
    ctx = flor.get_context()
    back, restore_s = ctx.restore_checkpoint(f"train@{B_CKPTS - 1}.0",
                                             like=state)
    identical = _leaves_equal(back, final_b)
    flor.finish()

    # --- registry gc: drop run A, keep exactly run B's closure -------------
    store = CheckpointStore(store_root)
    reg = RunRegistry(store_root)
    noop = reg.gc(store)
    assert noop["deleted_manifests"] == 0, "gc with all runs live must no-op"
    bytes_before = store.stored_bytes()
    reg.unregister("A")
    gc_stats = reg.gc(store)
    sb = CheckpointStore(store_root, run_id="B")
    post_gc_identical = _leaves_equal(
        final_b, sb.get_tree(f"train@{B_CKPTS - 1}.0", like=state))

    rows.add("lineage_warmstart", "logical_mb", round(logical / 2**20, 2),
             "per-checkpoint state size")
    rows.add("lineage_warmstart", "hot_fraction", round(hot_frac, 4))
    rows.add("lineage_warmstart", "first_ckpt_kind", first_stat["kind"],
             f"parent {first_stat['parent']}")
    rows.add("lineage_warmstart", "first_ckpt_transfer_fraction_warm",
             round(warm_frac, 4), f"expected ~{hot_frac:.4f} (hot fraction)")
    rows.add("lineage_warmstart", "first_ckpt_transfer_fraction_cold",
             round(cold_frac, 4), "fresh store: full recording")
    rows.add("lineage_warmstart", "warmstart_savings_x",
             round(cold_frac / max(warm_frac, 1e-9), 1),
             "first-checkpoint DMA, cold vs warm")
    rows.add("lineage_warmstart", "warm_start_s", round(warm_s, 3),
             "restore parent final + digest rehydration")
    rows.add("lineage_warmstart", "replay_restore_s", round(restore_s, 3),
             "derived-run restore through ancestor chunks")
    rows.add("lineage_warmstart", "replay_bit_identical", identical)
    rows.add("lineage_warmstart", "gc_deleted_manifests",
             gc_stats["deleted_manifests"], "run A dropped from registry")
    rows.add("lineage_warmstart", "gc_reclaimed_mb",
             round(gc_stats["deleted_bytes"] / 2**20, 2),
             f"of {bytes_before / 2**20:.2f} MiB")
    rows.add("lineage_warmstart", "post_gc_bit_identical", post_gc_identical,
             "run B restores through surviving ancestor chunks")

    assert first_stat["kind"] == "delta", \
        "warm-started first checkpoint must be a cross-run delta"
    assert warm_frac < 2.5 * hot_frac, \
        f"warm first-checkpoint transfer {warm_frac:.4f} should track hot " \
        f"fraction {hot_frac:.4f}"
    assert cold_frac > 0.99, "cold store must transfer everything"
    assert identical, "derived-run replay diverged"
    assert gc_stats["deleted_manifests"] > 0, \
        "dropping run A must reclaim its off-chain manifests"
    assert post_gc_identical, "gc broke run B's ancestor closure"


if __name__ == "__main__":
    run(Rows())
