"""Paper Fig. 5: background materialization — main-thread blocked time.

Baseline = serialize+compress+write synchronously on the main thread (the
paper's cloudpickle baseline); Fork/our-equivalent = AsyncWriter (JAX arrays
are immutable so the snapshot is a reference; DMA + serialization happen on
the writer thread). The metric is how long the TRAINING thread is stalled.
"""
from __future__ import annotations

import shutil
import time

import jax
import jax.numpy as jnp

from benchmarks.common import Rows, timed
from repro.checkpoint import AsyncWriter, CheckpointStore


def _big_state(mb=128):
    n = mb * 1024 * 1024 // 4
    return {"params": jax.random.normal(jax.random.PRNGKey(0), (n,)),
            "mu": jax.random.normal(jax.random.PRNGKey(1), (n // 2,)),
            }


def run(rows: Rows, tmp="/tmp/bench_bgmat"):
    tree = _big_state()
    nbytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(tree))

    shutil.rmtree(tmp, ignore_errors=True)
    store = CheckpointStore(f"{tmp}/sync")
    _, t_sync = timed(store.put_tree, "ck", jax.device_get(tree))

    store2 = CheckpointStore(f"{tmp}/async")
    w = AsyncWriter(store2)
    _, t_submit = timed(w.submit, "ck", tree)
    _, t_drain = timed(w.close)

    rows.add("background_mat(fig5)", "checkpoint_mb", nbytes // 2 ** 20)
    rows.add("background_mat(fig5)", "sync_main_thread_s", round(t_sync, 3),
             "cloudpickle-style baseline")
    rows.add("background_mat(fig5)", "async_main_thread_s",
             round(t_submit, 4), "AsyncWriter submit (reference snapshot)")
    rows.add("background_mat(fig5)", "async_background_s", round(t_drain, 3))
    rows.add("background_mat(fig5)", "main_thread_speedup",
             round(t_sync / max(t_submit, 1e-9), 1))


if __name__ == "__main__":
    run(Rows())
