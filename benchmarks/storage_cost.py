"""Paper Table 4: checkpoint storage footprint and S3 $/month.

Also quantifies what the paper's lean checkpointing becomes here: chunk-level
content dedup — the fine-tune-like workload (frozen majority) stores a small
fraction of its logical bytes.
"""
from __future__ import annotations

import shutil

import jax
import jax.numpy as jnp

import repro.flor as flor
from benchmarks.common import (Rows, S3_USD_PER_GB_MONTH, finetune_like,
                               make_runner, train_like)

EPOCHS = 8


def _record(cfg, kw, run_dir):
    shutil.rmtree(run_dir, ignore_errors=True)
    state0, run_epoch = make_runner(cfg, **kw)
    flor.init(run_dir, mode="record", adaptive=False)
    state = state0
    logical = 0
    for e in flor.generator(range(EPOCHS)):
        if flor.skipblock.step_into("train"):
            state, _ = run_epoch(state, e)
        state = flor.skipblock.end("train", state)
        from repro.utils.pytree import tree_bytes
        logical += tree_bytes(state)
    ctx = flor.get_context()
    ctx.pipeline.drain()
    stored = ctx.store.stored_bytes()
    # device->host bytes the delta pipeline actually moved (vs `logical`,
    # which is what the pre-pipeline full-transfer path copied every epoch)
    transferred = sum(s.get("transferred_bytes", 0) for s in ctx.pipeline.stats)
    flor.finish()
    return logical, stored, transferred


def run(rows: Rows, tmp="/tmp/bench_storage"):
    for name, (cfg, kw) in (("train_like", train_like()),
                            ("finetune_like", finetune_like())):
        logical, stored, transferred = _record(cfg, kw, f"{tmp}/{name}")
        gb = stored / 2 ** 30
        rows.add("storage_cost(table4)", f"{name}_logical_mb",
                 round(logical / 2 ** 20, 1), f"{EPOCHS} epoch ckpts")
        rows.add("storage_cost(table4)", f"{name}_stored_mb",
                 round(stored / 2 ** 20, 1), "post dedup+compression")
        rows.add("storage_cost(table4)", f"{name}_transferred_mb",
                 round(transferred / 2 ** 20, 1), "delta pipeline DMA")
        rows.add("storage_cost(table4)", f"{name}_compression_x",
                 round(logical / max(stored, 1), 1))
        rows.add("storage_cost(table4)", f"{name}_s3_usd_month",
                 round(gb * S3_USD_PER_GB_MONTH, 4), "paper: <$1/mo")


if __name__ == "__main__":
    run(Rows())
