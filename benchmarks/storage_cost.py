"""Paper Table 4: checkpoint storage footprint and S3 $/month, plus the
adaptive wire-encoding acceptance gates.

Two sections:

* **table4** — the florbench workload pair recorded through the Session
  API; logical vs stored vs transferred bytes, stored-bytes-per-checkpoint,
  and the S3 cost the paper prices.
* **encodings** — direct pipeline A/B runs over three slot classes
  (q4-eligible bounded, raw-fallback bounded, exact). These carry the
  PR's hard gates (asserted in-harness, so ``--strict`` CI fails on
  regression):

    - q4 wire >= 1.8x smaller than q8 on slots whose error bound admits it;
    - the writer-thread entropy stage >= 1.2x on a compressible slot class;
    - restored error <= the declared bound, exact slots bit-identical;
    - bounded-slot storage >= 2x smaller than the fixed-q8 policy
      (entropy off) those slots used before adaptive encodings;
    - auto full-manifest cadence restores no slower than the fixed-K
      default (<= 1.1x, with absolute slack for timer noise).

Run standalone: ``SMOKE=1 PYTHONPATH=src:. python -m
benchmarks.storage_cost``. SMOKE only shrinks sizes and step counts.
"""
from __future__ import annotations

import os
import shutil
import time

import numpy as np
import jax
import jax.numpy as jnp

import repro.flor as flor
from benchmarks.common import (Rows, S3_USD_PER_GB_MONTH, finetune_like,
                               make_runner, train_like)
from repro.checkpoint import CheckpointPipeline, CheckpointStore
from repro.utils.pytree import tree_bytes

SMOKE = bool(os.environ.get("SMOKE"))
EPOCHS = 4 if SMOKE else 8
ENC_ELEMS = 64 * 1024 if SMOKE else 256 * 1024   # f32 per encoded slot
ENC_STEPS = 6 if SMOKE else 12
CHUNK_WORDS = 1024


# ------------------------------------------------------ table4 workloads --
def _record(cfg, kw, run_dir):
    shutil.rmtree(run_dir, ignore_errors=True)
    state0, run_epoch = make_runner(cfg, **kw)
    logical = 0
    with flor.Session(run_dir, mode="record",
                      record=flor.RecordSpec(adaptive=False)) as sess:
        with sess.checkpointing(state=state0) as ckpt:
            for e in sess.loop("epochs", range(EPOCHS)):
                for _ in sess.loop("train", range(1)):
                    ckpt.state, _ = run_epoch(ckpt.state, e)
                logical += tree_bytes(ckpt.state)
        ctx = sess.ctx
        ctx.pipeline.drain()
        stored = ctx.store.stored_bytes()
        # device->host bytes the delta pipeline actually moved (vs
        # `logical`, what the pre-pipeline full-transfer path copied)
        transferred = sum(s.get("transferred_bytes", 0)
                          for s in ctx.pipeline.stats)
    return logical, stored, transferred


def run_table4(rows: Rows, tmp="/tmp/bench_storage"):
    for name, (cfg, kw) in (("train_like", train_like()),
                            ("finetune_like", finetune_like())):
        logical, stored, transferred = _record(cfg, kw, f"{tmp}/{name}")
        gb = stored / 2 ** 30
        rows.add("storage_cost(table4)", f"{name}_logical_mb",
                 round(logical / 2 ** 20, 1), f"{EPOCHS} epoch ckpts")
        rows.add("storage_cost(table4)", f"{name}_stored_mb",
                 round(stored / 2 ** 20, 1), "post dedup+compression")
        rows.add("storage_cost(table4)", f"{name}_stored_kb_per_ckpt",
                 round(stored / EPOCHS / 2 ** 10, 1),
                 "marginal footprint of one more checkpoint")
        rows.add("storage_cost(table4)", f"{name}_transferred_mb",
                 round(transferred / 2 ** 20, 1), "delta pipeline DMA")
        rows.add("storage_cost(table4)", f"{name}_compression_x",
                 round(logical / max(stored, 1), 1))
        rows.add("storage_cost(table4)", f"{name}_s3_usd_month",
                 round(gb * S3_USD_PER_GB_MONTH, 4), "paper: <$1/mo")


# --------------------------------------------------- encoding A/B gates --
# Three slot classes drive the gates, recorded one per store so stored
# bytes attribute cleanly:
#   mu — low-amplitude smooth f32 under atol 1e-3: the selector picks q4
#        (absmax/13.5 <= atol) on every chunk;
#   nu — unit-amplitude smooth f32 under a bound too tight for any lossy
#        encoding: raw fallback WITHIN a lossy policy, the slot class the
#        byte-plane-shuffle entropy stage exists for;
#   w  — exact (no policy): must stay bit-identical everywhere.
# The store compresses every chunk at rest, so all ratios below are
# at-rest bytes — what actually lands on disk / S3.

def _mu_slot(step: int) -> np.ndarray:
    x = np.linspace(0.0, 60.0, ENC_ELEMS, dtype=np.float32)
    return (0.01 * np.sin(x * (1.0 + 0.05 * step) + step)) \
        .astype(np.float32)


def _nu_slot(step: int) -> np.ndarray:
    x = np.linspace(0.0, 60.0, ENC_ELEMS, dtype=np.float32)
    return np.sin(x * (1.0 + 0.05 * step) + 2.0 * step).astype(np.float32)


def _exact_slot(step: int) -> np.ndarray:
    rng = np.random.default_rng(1000 + step)
    return rng.normal(size=ENC_ELEMS // 4).astype(np.float32)


def _record_encoded(root, tree_of_step, *, error_bounds=None,
                    quantize_slots=None, entropy=True, full_every=8,
                    calib=None):
    """Record ENC_STEPS checkpoints of ``tree_of_step(i)``; returns
    (store, at-rest stored bytes)."""
    shutil.rmtree(root, ignore_errors=True)
    store = CheckpointStore(os.path.join(root, "store"))
    if calib:
        store.put_meta("store_calib", calib)
    pipe = CheckpointPipeline(store, chunk_words=CHUNK_WORDS,
                              full_every=full_every, async_stage=True,
                              error_bounds=error_bounds,
                              quantize_slots=quantize_slots,
                              entropy=entropy)
    for i in range(ENC_STEPS):
        pipe.submit(f"ck{i}", {k: jnp.asarray(v)
                               for k, v in tree_of_step(i).items()},
                    block=True)
    pipe.drain()
    stored = store.stored_bytes()
    pipe.close()
    return store, stored


def _chain_hops(store, key):
    """Delta-manifest hops from `key` back to its full ancestor."""
    hops = 0
    m = store.get_manifest(key)
    while m.get("kind") == "delta":
        hops += 1
        m = store.get_manifest(m["parent"])
    return hops


def _restore_wall(store, key, like, trials=5):
    ts = []
    for _ in range(trials):
        t0 = time.perf_counter()
        store.get_tree(key, like=like)
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def run_encodings(rows: Rows, tmp="/tmp/bench_storage_enc"):
    atol = 1e-3
    bench = "storage_cost(encodings)"
    mu_tree = lambda i: {"mu": _mu_slot(i)}           # noqa: E731
    nu_tree = lambda i: {"nu": _nu_slot(i)}           # noqa: E731

    # -- gate: q4 >= 1.8x smaller than the fixed-q8 policy on mu ---------
    _, b_q8 = _record_encoded(f"{tmp}/q8", mu_tree,
                              quantize_slots=("mu",), entropy=False)
    _, b_q4 = _record_encoded(f"{tmp}/q4", mu_tree,
                              error_bounds={"mu": atol}, entropy=False)
    _, b_ad = _record_encoded(f"{tmp}/adaptive", mu_tree,
                              error_bounds={"mu": atol}, entropy=True)
    rows.add(bench, "mu_stored_q8_kb", round(b_q8 / 2 ** 10, 1),
             f"{ENC_STEPS} ckpts, fixed q8 (pre-adaptive policy)")
    rows.add(bench, "mu_stored_q4_kb", round(b_q4 / 2 ** 10, 1),
             f"error bound {atol} -> q4 selected per chunk")
    rows.add(bench, "mu_stored_adaptive_kb", round(b_ad / 2 ** 10, 1),
             "q4 + writer-thread entropy stage")
    r_q4 = b_q8 / max(b_q4, 1)
    rows.add(bench, "q4_vs_q8_shrink_x", round(r_q4, 2), "gate: >= 1.8x")
    assert r_q4 >= 1.8, f"q4 shrink {r_q4:.2f}x < 1.8x over q8"
    r_total = b_q8 / max(b_ad, 1)
    rows.add(bench, "adaptive_vs_q8_shrink_x", round(r_total, 2),
             "gate: >= 2x vs the fixed-q8 policy")
    assert r_total >= 2.0, \
        f"adaptive encodings shrink {r_total:.2f}x < 2x vs fixed q8"

    # -- gate: entropy >= 1.2x on the raw-fallback slot class ------------
    # nu's bound admits no lossy encoding (absmax/126 >> 1e-9), so its
    # chunks ship raw and the entropy stage byte-plane-shuffles the f32
    # payload — the transform the store's own at-rest compressor lacks.
    _, b_nu = _record_encoded(f"{tmp}/nu_plain", nu_tree,
                              error_bounds={"nu": 1e-9}, entropy=False)
    s_nu_z, b_nu_z = _record_encoded(f"{tmp}/nu_entropy", nu_tree,
                                     error_bounds={"nu": 1e-9},
                                     entropy=True)
    rows.add(bench, "nu_stored_plain_kb", round(b_nu / 2 ** 10, 1),
             "raw fallback, store at-rest compression only")
    rows.add(bench, "nu_stored_entropy_kb", round(b_nu_z / 2 ** 10, 1),
             "+ byte-plane shuffle off the step path")
    r_z = b_nu / max(b_nu_z, 1)
    rows.add(bench, "entropy_shrink_x", round(r_z, 2),
             "gate: >= 1.2x on the raw-fallback slot class")
    assert r_z >= 1.2, f"entropy stage shrink {r_z:.2f}x < 1.2x"
    nu_lf = {l["path"]: l for l in
             s_nu_z.resolve_manifest("ck0")["leaves"]}["[\'nu\']"]
    assert any(e == "raw+z" for e in nu_lf["enc"]), \
        "entropy stage left no raw+z chunks on the compressible slot"

    # -- gate: bound respected, exact slots bit-identical ----------------
    last = ENC_STEPS - 1
    full = lambda i: {"mu": _mu_slot(i), "nu": _nu_slot(i),   # noqa: E731
                      "w": _exact_slot(i)}
    s_all, b_all = _record_encoded(f"{tmp}/all", full,
                                   error_bounds={"mu": atol, "nu": 1e-9},
                                   entropy=True)
    rows.add(bench, "kb_per_ckpt_adaptive",
             round(b_all / ENC_STEPS / 2 ** 10, 1),
             "mu+nu+w tree, all encodings live")
    like = {"mu": np.empty(ENC_ELEMS, np.float32),
            "nu": np.empty(ENC_ELEMS, np.float32),
            "w": np.empty(ENC_ELEMS // 4, np.float32)}
    out = s_all.get_tree(f"ck{last}", like=like)
    err = float(np.max(np.abs(out["mu"] - _mu_slot(last))))
    rows.add(bench, "mu_restore_max_err", round(err, 6),
             f"gate: <= declared bound {atol}")
    assert err <= atol, f"restored error {err} exceeds bound {atol}"
    assert np.array_equal(out["nu"], _nu_slot(last)), \
        "raw-fallback chunks must stay exact despite the lossy policy"
    assert np.array_equal(out["w"], _exact_slot(last)), \
        "exact slot not bit-identical through the adaptive store"
    rows.add(bench, "exact_slots_bit_identical", 1,
             "w (no policy) and nu (raw fallback)")

    # -- gate: auto full-manifest cadence restores no slower than fixed --
    s_fix, _ = _record_encoded(f"{tmp}/cadence_fixed", full,
                               error_bounds={"mu": atol}, full_every=8)
    s_auto, _ = _record_encoded(
        f"{tmp}/cadence_auto", full, error_bounds={"mu": atol},
        full_every="auto",
        calib={"read_bps": 200e6, "hop_s": 0.01})   # restore-bound store
    t_fix = _restore_wall(s_fix, f"ck{last}", like)
    t_auto = _restore_wall(s_auto, f"ck{last}", like)
    hops_fix = _chain_hops(s_fix, f"ck{last}")
    hops_auto = _chain_hops(s_auto, f"ck{last}")
    rows.add(bench, "fixed_chain_hops", hops_fix)
    rows.add(bench, "auto_chain_hops", hops_auto,
             "restore-bound calib -> shorter chains")
    rows.add(bench, "auto_vs_fixed_restore_x",
             round(t_auto / max(t_fix, 1e-9), 2), "gate: <= 1.1x")
    assert hops_auto <= hops_fix, \
        f"auto cadence lengthened chains ({hops_auto} > {hops_fix}) on a " \
        "restore-bound store"
    assert t_auto <= 1.1 * t_fix + 0.05, \
        f"auto-cadence restore {t_auto:.4f}s > 1.1x fixed {t_fix:.4f}s"


def run(rows: Rows, tmp="/tmp/bench_storage"):
    run_table4(rows, tmp=tmp)
    run_encodings(rows, tmp=f"{tmp}_enc")


if __name__ == "__main__":
    run(Rows())
