"""Shared benchmark scaffolding: the florbench workload pair.

Two CPU-scale workloads mirror the paper's two regimes:
  * train-like  — compute-heavy epochs, modest state (paper: Cifr/RsNt/...);
  * finetune-like — short epochs, state dominated by a frozen majority
    (paper: RTE/CoLA) — the adaptive-checkpointing stress case.
"""
from __future__ import annotations

import shutil
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.data import synthetic_batch
from repro.train.step import build_train_step

S3_USD_PER_GB_MONTH = 0.023
P3_8XLARGE_USD_HR = 12.24        # paper's 4-GPU machine
P3_2XLARGE_USD_HR = 3.06


def train_like():
    cfg = C.get_smoke("florbench-100m")
    return cfg, dict(steps_per_epoch=8, batch=4, seq=128)


def finetune_like():
    # big params relative to per-epoch compute: 2 steps on short seq
    cfg = C.get_smoke("florbench-100m").replace(
        num_layers=6, d_model=256, d_ff=1024, vocab_size=8192)
    return cfg, dict(steps_per_epoch=1, batch=2, seq=32)


def make_runner(cfg, steps_per_epoch, batch, seq, seed=0):
    init_state, train_step = build_train_step(cfg)
    ts = jax.jit(train_step)
    state0 = jax.jit(init_state)(jax.random.PRNGKey(seed))

    def run_epoch(state, epoch):
        m = None
        for s in range(steps_per_epoch):
            b = synthetic_batch(cfg, batch, seq, epoch * steps_per_epoch + s,
                                seed)
            state, m = ts(state, b)
        jax.block_until_ready(m["loss"])
        return state, m

    # warm the jit cache so measurements exclude compilation
    warm, _ = run_epoch(state0, 10 ** 6)
    del warm
    return state0, run_epoch


class Rows:
    def __init__(self):
        self.rows = []

    def add(self, bench, metric, value, note=""):
        self.rows.append((bench, metric, value, note))
        print(f"{bench},{metric},{value},{note}", flush=True)


def timed(fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    return out, time.perf_counter() - t0
