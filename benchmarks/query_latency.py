"""Query latency: the incremental sqlite index vs the file scan.

FlorDB's pitch is that accumulated training logs are a RELATION — and a
relation you query more than once deserves an index. This harness builds a
store of many synthetic runs (each with sealed log segments holding scalar
metrics AND bulky histogram rows — the shape that makes file scans hurt),
then measures ``pivot("loss")`` through both engines.

Acceptance gate (--strict): the indexed pivot must return IDENTICAL rows at
>= 10x the file-scan's speed over >= 50 runs. The index wins by never
parsing the bulky rows a key-filtered query doesn't touch — the SQL key
pushdown skips them; the scan must JSON-parse everything.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

from repro.checkpoint.lineage import RunRegistry
from repro.core.query import log_records, pivot
from repro.logging.segment import SegmentSink
from repro.querydb import reindex

N_RUNS = 50
EPOCHS = 12 if os.environ.get("SMOKE") else 20
HIST = 2048
SPEEDUP_GATE = 10.0


def _build_store(root: str) -> None:
    registry = RunRegistry(root)
    parent = None
    for i in range(N_RUNS):
        rid = f"run{i:03d}"
        run_dir = os.path.join(root, "..", "runs", rid)
        registry.register(rid, parent=parent,
                          run_dir=os.path.abspath(run_dir))
        sink = SegmentSink(os.path.join(run_dir, "logs", "record.jsonl"),
                           roll_bytes=1 << 16)
        seq = 0
        for e in range(EPOCHS):
            for key, value in (("loss", 1.0 / (e + 1) + 0.01 * i),
                               ("acc", 0.04 * e),
                               ("hist", [float((seq * 7 + j) % 97)
                                         for j in range(HIST)])):
                sink.append(json.dumps({"epoch": e, "seq": seq, "key": key,
                                        "value": value}) + "\n", seq)
                seq += 1
        sink.close()
        parent = rid                   # one long lineage chain


def _best_of(n, fn):
    best, out = None, None
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return out, best


def run(rows) -> None:
    b = "query_latency"
    tmp = tempfile.mkdtemp(prefix="flor_qbench_")
    store = os.path.join(tmp, "store")
    try:
        _build_store(store)
        n_rows = len(log_records(store, engine="files"))
        rows.add(b, "runs", N_RUNS)
        rows.add(b, "log_rows", n_rows, f"{EPOCHS} epochs x 3 keys per run")

        piv_files, t_files = _best_of(
            1, lambda: pivot(store, "loss", engine="files"))
        rows.add(b, "pivot_filescan_s", round(t_files, 4))

        _stats, t_reindex = _best_of(1, lambda: reindex(store))
        rows.add(b, "reindex_s", round(t_reindex, 4),
                 f"{_stats['records']} records indexed")

        piv_idx, t_idx = _best_of(
            3, lambda: pivot(store, "loss", engine="index"))
        rows.add(b, "pivot_indexed_s", round(t_idx, 4), "best of 3")

        identical = piv_idx == piv_files
        speedup = t_files / max(t_idx, 1e-9)
        rows.add(b, "rows_identical", identical, "bit-identity contract")
        rows.add(b, "pivot_speedup_x", round(speedup, 1),
                 f"gate: >= {SPEEDUP_GATE}x")

        # lineage-chain aggregation (recursive CTE) for scale color
        leaf = f"run{N_RUNS - 1:03d}"
        lin_idx, t_lin = _best_of(
            3, lambda: pivot(store, "loss", lineage=leaf, engine="index"))
        rows.add(b, "lineage_pivot_indexed_s", round(t_lin, 4),
                 f"{len(lin_idx)} rows over a {N_RUNS}-run ancestor chain")

        # freshness check overhead: an auto query on a fully-fresh store
        # pays covers() (listdir+stat per stream) on top of the SQL
        _auto, t_auto = _best_of(
            3, lambda: pivot(store, "loss", engine="auto"))
        rows.add(b, "pivot_auto_fresh_s", round(t_auto, 4),
                 "includes per-run watermark freshness check")

        assert identical, "indexed pivot diverged from the file scan"
        assert speedup >= SPEEDUP_GATE, \
            f"indexed pivot only {speedup:.1f}x faster (< {SPEEDUP_GATE}x)"
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    from benchmarks.common import Rows
    run(Rows())
