"""Paper Fig. 12: replay latency by probe position.

Outer-loop probe -> partial replay (memoized epochs skipped, state restored
physically): latency is restore-bound. Inner-loop probe -> logical redo of
every epoch. Both compared against a vanilla re-execution.
"""
from __future__ import annotations

import shutil
import time

import repro.flor as flor
from benchmarks.common import Rows, make_runner, train_like

EPOCHS = 8


def _record(state0, run_epoch, run_dir):
    shutil.rmtree(run_dir, ignore_errors=True)
    flor.init(run_dir, mode="record", adaptive=False)
    state = state0
    for e in flor.generator(range(EPOCHS)):
        if flor.skipblock.step_into("train"):
            state, m = run_epoch(state, e)
            flor.log("loss", m["loss"])
        state = flor.skipblock.end("train", state)
    flor.finish()


def _replay(state0, run_epoch, run_dir, probed):
    flor.init(run_dir, mode="replay", probed=probed)
    t0 = time.perf_counter()
    state = state0
    for e in flor.generator(range(EPOCHS)):
        if flor.skipblock.step_into("train"):
            state, m = run_epoch(state, e)
        state = flor.skipblock.end("train", state)
        flor.log("outer_probe", float(state.step))   # hindsight outer probe
    wall = time.perf_counter() - t0
    flor.finish()
    return wall


def run(rows: Rows, tmp="/tmp/bench_replay"):
    cfg, kw = train_like()
    state0, run_epoch = make_runner(cfg, **kw)
    run_dir = f"{tmp}/run"
    _record(state0, run_epoch, run_dir)

    t0 = time.perf_counter()
    state = state0
    for e in range(EPOCHS):
        state, _ = run_epoch(state, e)
    t_vanilla = time.perf_counter() - t0

    t_outer = _replay(state0, run_epoch, run_dir, probed=set())
    t_inner = _replay(state0, run_epoch, run_dir, probed={"train"})

    rows.add("replay_latency(fig12)", "vanilla_s", round(t_vanilla, 3))
    rows.add("replay_latency(fig12)", "outer_probe_s", round(t_outer, 3),
             "partial replay: skip+restore")
    rows.add("replay_latency(fig12)", "outer_probe_speedup",
             round(t_vanilla / max(t_outer, 1e-9), 1), "paper: 7x-1123x")
    rows.add("replay_latency(fig12)", "inner_probe_s", round(t_inner, 3),
             "full logical redo (1 worker)")
    rows.add("replay_latency(fig12)", "inner_probe_speedup",
             round(t_vanilla / max(t_inner, 1e-9), 2),
             "~1x serial; parallelism = fig13")


if __name__ == "__main__":
    run(Rows())
