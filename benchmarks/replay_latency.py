"""Paper Fig. 12 + planned replay: latency by probe position, and
cost-balanced vs contiguous partitioning on a SKEWED run.

Part 1 (fig12): outer-loop probe -> partial replay (memoized epochs
skipped, state restored physically): latency is restore-bound. Inner-loop
probe -> logical redo of every epoch. Both vs a vanilla re-execution.
Runs on the session surface through the replay planner.

Part 2 (skew): epochs with wildly non-uniform compute (a few heavy epochs
among many light ones — think curriculum phases or data-size drift). The
record-side block profile measures the skew; the planner's per-segment
cost estimates expose it; LPT partitioning then beats the blind contiguous
split by construction: contiguous lands both heavy epochs on ONE worker.
Workers run serially here (1-CPU container) and parallel wall = max over
workers — the coordination-free bound (workers never communicate).
Asserts: balanced >= 1.3x faster than contiguous, deferred check ok, and
the per-segment MERGED multi-worker log is bit-identical to a
single-worker replay of the same plan.
"""
from __future__ import annotations

import shutil
import time

import jax

import repro.flor as flor
from benchmarks.common import Rows, make_runner, train_like
from repro.core.query import merge_replay_logs
from repro.replay import balanced_shares, build_plan, contiguous_shares

EPOCHS = 8
# part-2 skew: steps per epoch — two adjacent heavy epochs at the end is
# the contiguous split's worst case (both land on the last worker)
SKEW = [1, 1, 1, 1, 1, 1, 16, 16]


# ------------------------------------------------------------------ fig12 --
def _session_loop(run_dir, mode, state0, run_epoch, probed=frozenset(),
                  plan=None, outer_probe=False):
    spec = flor.ReplaySpec(probed=probed, plan=plan) if mode == "replay" \
        else None
    kw = {"replay": spec} if mode == "replay" else \
        {"record": flor.RecordSpec(adaptive=False)}
    with flor.Session(run_dir, mode=mode, **kw) as sess:
        state = state0
        with sess.checkpointing(state=state) as ckpt:
            for e in sess.loop("epochs", range(EPOCHS)):
                for _ in sess.loop("train", range(1)):
                    ckpt.state, m = run_epoch(ckpt.state, e)
                    flor.log("loss", m["loss"])
                if outer_probe:
                    flor.log("outer_probe", float(ckpt.state.step))
        return ckpt.state


def run_fig12(rows: Rows, tmp="/tmp/bench_replay"):
    cfg, kw = train_like()
    state0, run_epoch = make_runner(cfg, **kw)
    run_dir = f"{tmp}/run"
    shutil.rmtree(run_dir, ignore_errors=True)
    _session_loop(run_dir, "record", state0, run_epoch)

    t0 = time.perf_counter()
    state = state0
    for e in range(EPOCHS):
        state, _ = run_epoch(state, e)
    t_vanilla = time.perf_counter() - t0

    # outer probe: restore-only plan (no probed inner blocks)
    plan = build_plan(run_dir, probed=set())
    t0 = time.perf_counter()
    _session_loop(run_dir, "replay", state0, run_epoch, plan=plan,
                  outer_probe=True)
    t_outer = time.perf_counter() - t0

    # inner probe: every epoch re-executes logically
    plan = build_plan(run_dir, probed={"train"})
    t0 = time.perf_counter()
    _session_loop(run_dir, "replay", state0, run_epoch,
                  probed=frozenset({"train"}), plan=plan)
    t_inner = time.perf_counter() - t0

    rows.add("replay_latency(fig12)", "vanilla_s", round(t_vanilla, 3))
    rows.add("replay_latency(fig12)", "outer_probe_s", round(t_outer, 3),
             "partial replay: skip+restore")
    rows.add("replay_latency(fig12)", "outer_probe_speedup",
             round(t_vanilla / max(t_outer, 1e-9), 1), "paper: 7x-1123x")
    rows.add("replay_latency(fig12)", "inner_probe_s", round(t_inner, 3),
             "full logical redo (1 worker)")
    rows.add("replay_latency(fig12)", "inner_probe_speedup",
             round(t_vanilla / max(t_inner, 1e-9), 2),
             "~1x serial; parallelism = fig13 / skew below")


# ------------------------------------------------------------- skewed run --
def _skew_loop(run_dir, mode, state0, run_step, pid=0, visits=None,
               probed=frozenset()):
    spec = flor.ReplaySpec(pid=pid, segments=visits, probed=probed) \
        if mode == "replay" else None
    kw = {"replay": spec} if mode == "replay" else \
        {"record": flor.RecordSpec(adaptive=False)}
    with flor.Session(run_dir, mode=mode, **kw) as sess:
        state = state0
        with sess.checkpointing(state=state) as ckpt:
            for e in sess.loop("epochs", range(EPOCHS)):
                base = sum(SKEW[:e])
                for s in sess.loop("train", range(SKEW[e])):
                    ckpt.state, m = run_step(ckpt.state, base + s)
                    if mode == "replay":
                        flor.log("probe", m["grad_norm"])   # hindsight probe
                if sess.executed("train"):
                    flor.log("loss", m["loss"])
        return ckpt.state


def run_skew(rows: Rows, tmp="/tmp/bench_replay_skew"):
    import repro.configs as C
    from repro.data import synthetic_batch
    from repro.train.step import build_train_step
    cfg = C.get_smoke("florbench-100m")
    init_state, train_step = build_train_step(cfg)
    ts = jax.jit(train_step)
    state0 = jax.jit(init_state)(jax.random.PRNGKey(0))

    def run_step(state, i):
        state, m = ts(state, synthetic_batch(cfg, 4, 128, i, 0))
        jax.block_until_ready(m["loss"])
        return state, m

    state0, _ = run_step(state0, 10 ** 6)       # warm the jit cache
    run_dir = f"{tmp}/run"
    shutil.rmtree(run_dir, ignore_errors=True)
    _skew_loop(run_dir, "record", state0, run_step)

    plan = build_plan(run_dir, probed={"train"})
    work = plan.work_segments()
    rows.add("replay_skew", "plan",
             f"{len(plan.exec_segments())}/{len(plan.segments)} exec",
             "; ".join(f"e{s.epoch}:{s.cost:.2f}s" for s in work))

    # single worker: the merge baseline (pid 9 keeps its log distinct)
    single = _run_share(run_dir, state0, run_step, plan, 9,
                        plan.visits_for())
    merged_single = merge_replay_logs(
        run_dir, [("replay_p9", [s.epoch for s in work])])

    results = {}
    for label, split in (("contiguous", contiguous_shares),
                         ("balanced", balanced_shares)):
        shares = [sh for sh in split(work, 2) if sh]
        walls, owners = [], []
        for pid, sh in enumerate(shares):
            walls.append(_run_share(run_dir, state0, run_step, plan, pid,
                                    plan.visits_for(sh)))
            owners.append((f"replay_p{pid}", [s.epoch for s in sh]))
        wall = max(walls)    # parallel wall: workers never communicate
        results[label] = wall
        merged = merge_replay_logs(run_dir, owners)
        rec, _ = flor.run_logs(run_dir)
        res = flor.deferred_check(rec, merged)
        assert res.ok, f"{label}: deferred check failed: {res.anomalies[:3]}"
        assert merged == merged_single, \
            f"{label}: merged multi-worker log differs from single-worker"
        rows.add("replay_skew", f"{label}_wall_s", round(wall, 2),
                 f"per-worker {[round(w, 2) for w in walls]}")

    speedup = results["contiguous"] / max(results["balanced"], 1e-9)
    rows.add("replay_skew", "balanced_vs_contiguous",
             round(speedup, 2), "LPT over measured per-epoch cost")
    rows.add("replay_skew", "single_worker_s", round(single, 2))
    assert speedup >= 1.3, \
        f"cost-balanced partitioning only {speedup:.2f}x vs contiguous " \
        f"on a skewed run (expected >= 1.3x)"


def _run_share(run_dir, state0, run_step, plan, pid, visits) -> float:
    t0 = time.perf_counter()
    _skew_loop(run_dir, "replay", state0, run_step, pid=pid, visits=visits,
               probed=plan.probed)
    return time.perf_counter() - t0


def run(rows: Rows, tmp="/tmp/bench_replay"):
    run_fig12(rows, tmp)
    run_skew(rows, tmp + "_skew")


if __name__ == "__main__":
    run(Rows())
