"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve.py --arch granite-3-2b --steps 16

Uses the reduced config on CPU. Exercises the same prefill/decode step
functions the multi-pod dry-run lowers for the decode_32k / long_500k cells
(SWA ring caches, SSM states, MLA latent cache — per arch).
"""
import argparse
import time

import jax
import numpy as np

import repro.configs as C
from repro.data import synthetic_batch
from repro.models import build_model
from repro.serve.step import greedy_generate

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="granite-3-2b", choices=C.ARCHS + C.EXTRA)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--steps", type=int, default=16)
args = ap.parse_args()

cfg = C.get_smoke(args.arch)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
prompt = synthetic_batch(cfg, args.batch, args.prompt_len, 0)

t0 = time.time()
out = greedy_generate(cfg, params, prompt, steps=args.steps,
                      max_len=args.prompt_len + args.steps)
wall = time.time() - t0
print(f"arch={args.arch} family={cfg.family}")
print(f"generated {args.batch}x{args.steps} tokens in {wall:.2f}s "
      f"({args.batch * args.steps / wall:.1f} tok/s incl. compile)")
print("sample token ids:", np.asarray(out[0])[:12].tolist())
