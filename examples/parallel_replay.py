"""Hindsight parallelism, query-driven: record, EDIT the script to add the
log statement you wish you had, and let the replay planner work out the
minimal re-execution — then scale it over G workers.

    PYTHONPATH=src python examples/parallel_replay.py --nworkers 4

Flow:
  1. record a run with the stock training launcher (the record session
     stores a copy of the driving script automatically);
  2. simulate the hindsight edit: copy the recorded script and insert a
     ``flor.log`` probe INSIDE the training loop;
  3. replay with ``--probe auto``: the launcher diffs recorded vs edited
     source, maps the added line to its innermost enclosing flor loop
     ("train"), plans which epochs must re-execute at what cost, schedules
     them cost-balanced over G worker processes (dynamic work queue), and
     merges the per-worker logs by plan segment;
  4. the deferred fingerprint check must pass on the merged log.
"""
import argparse
import importlib.util
import os
import shutil
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ap = argparse.ArgumentParser()
ap.add_argument("--run-dir", default="/tmp/flor_parallel_replay")
ap.add_argument("--nworkers", type=int, default=4)
ap.add_argument("--epochs", type=int, default=8)
ap.add_argument("--init-mode", choices=("strong", "weak"), default="strong")
args = ap.parse_args()

# strict: the launchers run on the session surface; any deprecation-shim
# call escaping from them fails the example
env = dict(os.environ, PYTHONPATH=SRC, FLOR_STRICT_DEPRECATIONS="1")
shutil.rmtree(args.run_dir, ignore_errors=True)

print("== record ==", flush=True)
t0 = time.time()
subprocess.run([sys.executable, "-m", "repro.launch.train",
                "--arch", "florbench-100m", "--smoke",
                "--epochs", str(args.epochs), "--steps-per-epoch", "6",
                "--run-dir", args.run_dir, "--no-adaptive"],
               env=env, check=True)
print(f"record wall {time.time() - t0:.1f}s")

# the hindsight edit: add a probe line inside the train loop of the SAME
# script that recorded (here: the train launcher), exactly what a user does
# when training looked wrong and they wish they had logged more
try:
    train_py = importlib.util.find_spec("repro.launch.train").origin
except (ImportError, AttributeError):
    sys.path.insert(0, SRC)
    train_py = importlib.util.find_spec("repro.launch.train").origin
src_lines = open(train_py).read().splitlines(keepends=True)
anchor = next(i for i, ln in enumerate(src_lines)
              if "ckpt.state, m = ts(ckpt.state, b)" in ln)
indent = src_lines[anchor][: len(src_lines[anchor])
                           - len(src_lines[anchor].lstrip())]
probe = indent + 'flor.log("probe_grad_norm", m["grad_norm"])\n'
edited = os.path.join(args.run_dir, "train_probed.py")
with open(edited, "w") as f:
    f.writelines(src_lines[: anchor + 1] + [probe]
                 + src_lines[anchor + 1:])
print(f"== hindsight edit: probe inserted after line {anchor + 1} "
      f"-> {edited} ==")

print(f"== planned replay: --probe auto, {args.nworkers} workers ==",
      flush=True)
t0 = time.time()
subprocess.run([sys.executable, "-m", "repro.launch.replay",
                "--run-dir", args.run_dir, "--arch", "florbench-100m",
                "--smoke", "--epochs", str(args.epochs),
                "--steps-per-epoch", "6", "--nworkers", str(args.nworkers),
                "--probe", "auto", "--current-src", edited,
                "--init-mode", args.init_mode, "--check"],
               env=env, check=True)
print(f"replay wall {time.time() - t0:.1f}s "
      f"(workers are processes; on a cluster each maps to a pod slice)")
