"""Hindsight parallelism: replay a sequential training run on G workers.

    PYTHONPATH=src python examples/parallel_replay.py --nworkers 4

Records a run, then launches G coordination-free replay workers (separate
processes, as on a cluster) each re-executing its contiguous share of epochs
with per-step probes, and merges + checks the logs. Work partitioning and
strong/weak initialization are the paper's Fig. 9 machinery.
"""
import argparse
import os
import shutil
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ap = argparse.ArgumentParser()
ap.add_argument("--run-dir", default="/tmp/flor_parallel_replay")
ap.add_argument("--nworkers", type=int, default=4)
ap.add_argument("--epochs", type=int, default=8)
ap.add_argument("--init-mode", choices=("strong", "weak"), default="strong")
args = ap.parse_args()

# strict: the launchers run on the session surface; any deprecation-shim
# call escaping from them fails the example
env = dict(os.environ, PYTHONPATH=SRC, FLOR_STRICT_DEPRECATIONS="1")
shutil.rmtree(args.run_dir, ignore_errors=True)

print("== record ==", flush=True)
t0 = time.time()
subprocess.run([sys.executable, "-m", "repro.launch.train",
                "--arch", "florbench-100m", "--smoke",
                "--epochs", str(args.epochs), "--steps-per-epoch", "6",
                "--run-dir", args.run_dir, "--no-adaptive"],
               env=env, check=True)
print(f"record wall {time.time() - t0:.1f}s")

print(f"== parallel replay: {args.nworkers} workers, inner probe ==",
      flush=True)
t0 = time.time()
subprocess.run([sys.executable, "-m", "repro.launch.replay",
                "--run-dir", args.run_dir, "--arch", "florbench-100m",
                "--smoke", "--epochs", str(args.epochs),
                "--steps-per-epoch", "6", "--nworkers", str(args.nworkers),
                "--probe", "train", "--init-mode", args.init_mode,
                "--check"],
               env=env, check=True)
print(f"replay wall {time.time() - t0:.1f}s "
      f"(workers are processes; on a cluster each maps to a pod slice)")
