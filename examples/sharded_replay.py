"""Mesh-sharded record -> RESHARDED hindsight replay, end to end.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sharded_replay.py --run-dir /tmp/flor_sharded

(The script sets the flag itself when unset, so a bare invocation works.)

Scenario: a training run recorded on a (2, 4) device mesh — each device
fingerprints and gathers ONLY its own checkpoint shard (no all-gather;
bytes never cross devices), and each store shard keeps a delta chain of its
local bytes. Later you want per-step values you never logged, but the
original mesh is gone: replay runs on a (4, 2) mesh, an (1, 8) mesh, and a
plain unsharded session. `get_tree` reads only the chunks each target
shard needs and re-resolves the recorded partition specs through the
logical-axis rules, so every replay restores bit-identically.

The training update is ELEMENT-WISE on purpose: cross-mesh reduction
reorder would change float rounding, and the point here is byte equality —
each epoch logs a blake2 digest of the full state bytes, and the deferred
check compares the digests replayed on every mesh shape against record.
"""
import argparse
import hashlib
import os
import shutil
import sys
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402
from jax.sharding import Mesh, NamedSharding                 # noqa: E402
from jax.sharding import PartitionSpec as P                  # noqa: E402

import repro.flor as flor                                    # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--run-dir", default="/tmp/flor_sharded")
ap.add_argument("--epochs", type=int, default=4)
ap.add_argument("--steps-per-epoch", type=int, default=4)
args = ap.parse_args()

if len(jax.devices()) < 8:
    print(f"need 8 devices (got {len(jax.devices())}); set XLA_FLAGS "
          f"before any jax import")
    sys.exit(0)

SPECS = {"w": P("data", "model"), "b": P("model"), "scale": P()}


def make_mesh(shape):
    return Mesh(np.array(jax.devices()[:shape[0] * shape[1]])
                .reshape(shape), ("data", "model"))


def init_state(mesh):
    if mesh is None:
        return {"w": jnp.arange(64 * 128, dtype=jnp.float32)
                .reshape(64, 128),
                "b": jnp.linspace(-1.0, 1.0, 128, dtype=jnp.float32),
                "scale": jnp.float32(1.0)}
    st = init_state(None)
    return {k: jax.device_put(v, NamedSharding(mesh, SPECS[k]))
            for k, v in st.items()}


@jax.jit
def step_fn(state, delta):
    # element-wise only: identical bytes under ANY sharding of the mesh
    return {"w": state["w"] * 0.999 + delta,
            "b": state["b"] * 0.999 - delta,
            "scale": state["scale"] * 0.9999}


def digest(state) -> str:
    h = hashlib.blake2b(digest_size=16)
    for k in sorted(state):
        h.update(np.asarray(jax.device_get(state[k])).tobytes())
    return h.hexdigest()


def run(mode, mesh, probed=frozenset(), label=""):
    spec = {"record": dict(record=flor.RecordSpec(mesh=mesh)),
            "replay": dict(replay=flor.ReplaySpec(probed=probed))}[mode]
    t0 = time.time()
    digests = []
    with flor.Session(args.run_dir, mode=mode, **spec):
        epochs = flor.arg("epochs", args.epochs)
        steps = flor.arg("steps_per_epoch", args.steps_per_epoch)
        state = init_state(mesh)
        with flor.checkpointing(state=state) as ckpt:
            for epoch in flor.loop("epochs", range(epochs)):
                for s in flor.loop("train", range(steps)):
                    delta = jnp.float32(0.001 * (epoch * steps + s + 1))
                    ckpt.state = step_fn(ckpt.state, delta)
                    if "train" in probed:
                        # the hindsight probe: per-step state digest
                        flor.log("step_digest", digest(ckpt.state))
                d = digest(ckpt.state)
                digests.append(d)
                flor.log("digest", d)
    print(f"{label or mode}: {len(digests)} epochs, "
          f"{time.time() - t0:.1f}s, final digest {digests[-1]}")
    return digests


if os.path.isdir(args.run_dir):
    shutil.rmtree(args.run_dir)

# ---- record on a (2, 4) mesh: per-shard delta checkpoints ----
rec_digests = run("record", make_mesh((2, 4)), label="record (2,4)")

# ---- hindsight replays on meshes the record run never saw ----
# (each replay session reuses the pid-0 log; the inner-probe trial runs
# LAST so the surviving log carries its hindsight step_digest rows for the
# deferred check — cross-mesh bit-identity is asserted in-process below)
trials = [("replay (1,8) restore-only", make_mesh((1, 8)), frozenset()),
          ("replay unsharded", None, frozenset()),
          ("replay (4,2) inner probe", make_mesh((4, 2)),
           frozenset({"train"}))]
for label, mesh, probed in trials:
    d = run("replay", mesh, probed=probed, label=label)
    if d != rec_digests:
        print(f"FAIL: {label} digests diverge from record")
        sys.exit(1)

rec, reps = flor.run_logs(args.run_dir)
res = flor.deferred_check(rec, reps)
print(f"deferred check: ok={res.ok} compared={res.compared} "
      f"hindsight={res.hindsight_only}")
if not res.ok:
    for a in res.anomalies[:5]:
        print("  anomaly:", a)
    sys.exit(1)
print("OK: bit-identical state digests on (2,4) record vs "
      "(4,2)/(1,8)/unsharded replay")
