"""Hindsight logging: query execution data you never logged, after the fact.

    PYTHONPATH=src python examples/hindsight_replay.py --run-dir /tmp/flor_quickstart

Scenario (paper section 2.1): training looked wrong and you wish you had
logged per-step gradient norms and the embedding-norm trajectory. This
script "adds the log statements in hindsight" on the session API: the
outer-loop probe (embedding norm per epoch) needs NO re-execution — epochs
restore physically into the `flor.checkpointing` scope in seconds; the
inner probe (per-step grad norm) re-executes only the probed epochs
(`ReplaySpec(probed={"train"})`). `flor.arg` returns the RECORDED
hyperparameters, so the replay loop shape can never drift from record.
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp

import repro.configs as C
import repro.flor as flor
from repro.data import synthetic_batch
from repro.train.step import build_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--run-dir", default="/tmp/flor_quickstart")
ap.add_argument("--full", action="store_true")
ap.add_argument("--epochs", type=int, default=8)
ap.add_argument("--steps-per-epoch", type=int, default=25)
ap.add_argument("--probe-inner", action="store_true",
                help="probe INSIDE the training loop (forces re-execution)")
args = ap.parse_args()

cfg = C.get("florbench-100m") if args.full else C.get_smoke("florbench-100m")
batch_size, seq = (8, 512) if args.full else (4, 128)

probed = frozenset({"train"}) if args.probe_inner else frozenset()
t0 = time.time()
with flor.Session(args.run_dir, mode="replay",
                  replay=flor.ReplaySpec(probed=probed)) as sess:
    epochs = flor.arg("epochs", args.epochs)
    steps = flor.arg("steps_per_epoch", args.steps_per_epoch)
    peak_lr = flor.arg("peak_lr", 1e-3)

    init_state, train_step = build_train_step(cfg, peak_lr=peak_lr, warmup=20)
    ts = jax.jit(train_step)
    state = jax.jit(init_state)(jax.random.PRNGKey(0))

    with flor.checkpointing(state=state) as ckpt:
        for epoch in flor.loop("epochs", range(epochs)):
            for s in flor.loop("train", range(steps)):
                batch = synthetic_batch(cfg, batch_size, seq,
                                        epoch * steps + s)
                ckpt.state, metrics = ts(ckpt.state, batch)
                if args.probe_inner:
                    # the hindsight INNER probe you wish you'd written:
                    flor.log("grad_norm", metrics["grad_norm"])
            if flor.executed("train"):
                flor.log("loss", metrics["loss"])
            # the hindsight OUTER probe: embedding norm over time — computed
            # from the (restored) scope state, no re-execution needed
            emb = ckpt.state.params["embed"]["table"]
            flor.log("embed_norm",
                     float(jnp.linalg.norm(emb.astype(jnp.float32))))
            print(f"epoch {epoch}: embed_norm logged", flush=True)

mode = "inner-probe (logical redo)" if args.probe_inner else \
    "outer-probe (physical restore only)"
print(f"\nhindsight replay [{mode}] finished in {time.time() - t0:.1f}s")

rec, reps = flor.run_logs(args.run_dir)
res = flor.deferred_check(rec, reps)
print(f"deferred correctness check: ok={res.ok} compared={res.compared} "
      f"hindsight_values={res.hindsight_only}")
if not res.ok:
    for a in res.anomalies[:5]:
        print("  anomaly:", a)
    sys.exit(1)

# the new query surface: every logged value of this run (and any lineage
# sharing its store) as one pivoted table
rows = flor.pivot(args.run_dir, "loss", "embed_norm")
print(f"\nflor.pivot: {len(rows)} (run, epoch) rows; last: {rows[-1]}")
