"""True multi-process mesh record, crash injection, and recovery, end to end.

    PYTHONPATH=src python examples/distributed_record.py --run-dir /tmp/flor_dist

The script is its own fleet launcher: it re-execs itself twice with
``--child <process-id>`` so two REAL processes join a ``jax.distributed``
fleet over a loopback coordinator (4 forced host-platform devices each — a
2x4 global mesh). Each process records through the full Session path:

* it fingerprints + gathers ONLY the checkpoint shards its local devices
  own and publishes per-host member manifests crash-safely;
* the lead gathers every process's publication through the file rendezvous
  under ``<store>/runs/<run>/.stitch/`` and writes the v4 stitch atomically.

Round 1 proves the happy path: every epoch stitches, and the state restores
bit-identically both unsharded and on a DIFFERENT mesh layout.

Round 2 proves the crash-safety argument: ``FLOR_DIST_CRASH_BEFORE_PUBLISH``
kills process 1 in the exact window between durable member manifests and
its rendezvous marker. The store is never corrupted — the lead marks the
checkpoint incomplete, the run finalizes at the last COMPLETE checkpoint,
the replay planner skips the torn key, and GC reclaims the orphan members.

(The CPU backend cannot jit multi-process computations, so the children
compute their SPMD-replicated state locally and place it on the global mesh
with ``make_array_from_callback`` — exactly the layout a real multi-host
training step leaves behind, and the only part the checkpoint path sees.)
"""
import argparse
import os
import shutil
import socket
import subprocess
import sys

EPOCHS = 3
CRASH_KEY = "train@2.0"


def host_state(epoch):
    import numpy as np
    rng = np.random.default_rng(7)
    w = (rng.normal(size=(64, 32)).astype(np.float32)
         * (1.0 + 0.001 * epoch))
    b = np.arange(32, dtype=np.float32) * (2.0 + 0.001 * epoch)
    return {"w": w, "b": b}


# ------------------------------------------------------------------ child --
def child(run_dir: str, port: int, pid: int):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax
    import numpy as np
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    import repro.flor as flor
    from repro.parallel.rendezvous import StitchRendezvous, init_distributed

    group = init_distributed(f"127.0.0.1:{port}", pid, 2)
    assert jax.device_count() == 8 and jax.local_device_count() == 4
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    specs = {"w": P("data", "model"), "b": P("model")}

    def global_tree(epoch):
        h = host_state(epoch)
        return {k: jax.make_array_from_callback(
                    h[k].shape, NamedSharding(mesh, specs[k]),
                    lambda idx, a=h[k]: a[idx])
                for k in h}

    timeout = float(os.environ.get("T_STITCH", "30"))
    with flor.Session(run_dir, mode="record",
                      record=flor.RecordSpec(adaptive=False, mesh=mesh,
                                             distributed=group,
                                             stitch_timeout_s=timeout)) as s:
        with s.checkpointing(state=global_tree(0)) as ckpt:
            for epoch in s.loop("epochs", range(EPOCHS)):
                for _ in s.loop("train", range(2)):
                    pass
                ckpt.state = global_tree(epoch + 1)
                flor.log("epoch", epoch)
    # exit barrier: neither process may tear down the jax coordinator
    # (hosted by process 0) while its peer is still closing
    rdv = StitchRendezvous(os.path.join(run_dir, "store"),
                           "dist-" + os.path.basename(run_dir.rstrip("/")),
                           group, timeout_s=timeout)
    rdv.arrive("exit")
    rdv.await_all("exit")
    print(f"child {pid}: record complete", flush=True)
    os._exit(0)


# ----------------------------------------------------------- fleet driver --
def free_port() -> int:
    s = socket.socket()
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def run_fleet(run_dir: str, env_extra=None) -> list:
    if os.path.isdir(run_dir):
        shutil.rmtree(run_dir)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)       # children force their own 4 devices
    env.pop("JAX_PLATFORMS", None)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    env.update(env_extra or {})
    port = free_port()
    procs = [subprocess.Popen(
                 [sys.executable, os.path.abspath(__file__),
                  "--child", str(p), "--port", str(port),
                  "--run-dir", run_dir],
                 env=env)
             for p in (0, 1)]
    return [p.wait() for p in procs]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-dir", default="/tmp/flor_dist")
    ap.add_argument("--child", type=int, default=None)
    ap.add_argument("--port", type=int, default=0)
    args = ap.parse_args()
    if args.child is not None:
        child(args.run_dir, args.port, args.child)
        return

    # the parent does the cross-mesh restore itself: 8 forced devices
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.checkpoint import CheckpointStore, restore_sharded_tree
    from repro.checkpoint.lineage import RunRegistry
    from repro.replay.plan import build_plan

    # ---- round 1: clean 2-process record --------------------------------
    run = args.run_dir
    run_id = "dist-" + os.path.basename(run.rstrip("/"))
    print("== round 1: 2-process record over a (2, 4) mesh ==")
    rcs = run_fleet(run)
    assert rcs == [0, 0], f"fleet failed: exit codes {rcs}"

    store = CheckpointStore(os.path.join(run, "store"))
    keys = set(store.list_keys())
    for e in range(EPOCHS):
        m = store.get_manifest(f"train@{e}.0")
        assert m["version"] == 4 and len(m["members"]) == 8
    print(f"  {EPOCHS} checkpoints stitched, 8 member shards each")

    truth = host_state(2)            # train@2.0 = state ENTERING epoch 2
    like = {"state": {k: np.empty_like(v) for k, v in truth.items()}}
    got = store.get_tree("train@2.0", like=like)["state"]
    assert all(np.array_equal(got[k], truth[k]) for k in truth)
    print("  unsharded restore: bit-identical")

    if len(jax.devices()) >= 8:
        mesh = Mesh(np.array(jax.devices()[:8]).reshape(4, 2),
                    ("data", "model"))
        out = restore_sharded_tree(store, "train@2.0", mesh)
        for k in truth:
            arr = np.asarray(jax.device_get(out[f"['state']['{k}']"]))
            assert np.array_equal(arr, truth[k]), k
        print("  resharded (4, 2) restore: bit-identical")

    rec = {r["run_id"]: r
           for r in RunRegistry(os.path.join(run, "store")).list_runs()}
    assert rec[run_id]["status"] == "finished"
    assert rec[run_id]["final_keys"] == {"train": f"train@{EPOCHS - 1}.0"}
    print(f"  registry: finished at train@{EPOCHS - 1}.0")

    # ---- round 2: crash between publication and stitch ------------------
    crun = run.rstrip("/") + "_crash"
    print("== round 2: kill process 1 before it publishes", CRASH_KEY, "==")
    rcs = run_fleet(crun, env_extra={
        "T_STITCH": "6",
        "FLOR_DIST_CRASH_BEFORE_PUBLISH": CRASH_KEY,
        "FLOR_DIST_CRASH_PROCESS": "1",
    })
    assert rcs[0] == 0 and rcs[1] == 43, f"unexpected exit codes {rcs}"
    print(f"  exit codes {rcs}: survivor finished, victim crashed")

    cstore = CheckpointStore(os.path.join(crun, "store"))
    ckeys = set(cstore.list_keys())
    assert "train_at_2.0" not in ckeys            # no torn v4, ever
    orphans = [k for k in ckeys if k.startswith("train_at_2.0.shard")]
    assert orphans and cstore.get_meta("incomplete_ckpts") == \
        {"keys": [CRASH_KEY]}
    print(f"  no v4 for {CRASH_KEY}; {len(orphans)} orphan member(s); "
          f"checkpoint marked incomplete")

    creg = RunRegistry(os.path.join(crun, "store"))
    crec = {r["run_id"]: r for r in creg.list_runs()}[run_id + "_crash"]
    assert crec["final_keys"] == {"train": "train@1.0"}
    assert build_plan(crun).incomplete == ["train_at_2.0"]
    truth1 = host_state(1)
    like1 = {"state": {k: np.empty_like(v) for k, v in truth1.items()}}
    got1 = cstore.get_tree("train@1.0", like=like1)["state"]
    assert all(np.array_equal(got1[k], truth1[k]) for k in truth1)
    print("  run finalized at train@1.0; replay plan skips the torn key; "
          "last complete checkpoint restores bit-identically")

    res = creg.gc(cstore)
    assert res["deleted_manifests"] == len(orphans)
    got1 = cstore.get_tree("train@1.0", like=like1)["state"]
    assert all(np.array_equal(got1[k], truth1[k]) for k in truth1)
    print(f"  gc reclaimed {res['deleted_manifests']} orphan manifest(s) + "
          f"{res['deleted_chunks']} chunk(s); restore still intact")
    print("DISTRIBUTED_RECORD_OK")


if __name__ == "__main__":
    main()
