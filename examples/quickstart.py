"""Quickstart: train a model with Flor record on — the end-to-end driver.

    PYTHONPATH=src python examples/quickstart.py [--full]

Trains the florbench-100m model (reduced config by default so it runs on a
laptop CPU in ~2 minutes; --full trains the real 124M config) for a few
hundred steps with always-on hindsight-logging record, on the session-first
API: an explicit `flor.Session`, named nested `flor.loop`s, a declarative
`flor.checkpointing` scope, and replay-stable `flor.arg` hyperparameters.
Afterwards, see examples/hindsight_replay.py to query execution data you
never logged, and

    python -m repro.launch.runs pivot --store-root <run-dir>

to view the run's logs (and any lineage sharing its store) as a table.
"""
import argparse
import time

import jax

import repro.configs as C
import repro.flor as flor
from repro.data import PrefetchLoader, synthetic_batch
from repro.train.step import build_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true", help="real 124M config")
ap.add_argument("--epochs", type=int, default=8)
ap.add_argument("--steps-per-epoch", type=int, default=25)
ap.add_argument("--run-dir", default="/tmp/flor_quickstart")
ap.add_argument("--no-adaptive", action="store_true",
                help="checkpoint every epoch regardless of the eps budget "
                     "(useful on slow disks / CI to guarantee physical "
                     "replay restores)")
ap.add_argument("--sync-log", action="store_true",
                help="legacy synchronous flor.log (serialize + write on the "
                     "step path); default is the background log stage — see "
                     "docs/logging.md")
args = ap.parse_args()

cfg = C.get("florbench-100m") if args.full else C.get_smoke("florbench-100m")
batch_size, seq = (8, 512) if args.full else (4, 128)

t0 = time.time()
with flor.Session(args.run_dir, mode="record",
                  record=flor.RecordSpec(
                      adaptive=not args.no_adaptive,
                      async_log=not args.sync_log)) as sess:
    # hyperparameters recorded for replay (override: FLOR_ARGS="peak_lr=3e-4")
    epochs = flor.arg("epochs", args.epochs)
    steps = flor.arg("steps_per_epoch", args.steps_per_epoch)
    peak_lr = flor.arg("peak_lr", 1e-3)

    init_state, train_step = build_train_step(cfg, peak_lr=peak_lr, warmup=20)
    ts = jax.jit(train_step)
    state = jax.jit(init_state)(jax.random.PRNGKey(0))

    with flor.checkpointing(state=state) as ckpt:
        for epoch in flor.loop("epochs", range(epochs)):
            for step, batch in flor.loop("train", lambda: PrefetchLoader(
                    lambda s: synthetic_batch(cfg, batch_size, seq, s),
                    start_step=epoch * steps, num_steps=steps)):
                ckpt.state, metrics = ts(ckpt.state, batch)
            flor.log("loss", metrics["loss"])
            print(f"epoch {epoch}: loss={float(metrics['loss']):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    state = ckpt.state

print(f"\nrecorded {args.epochs} epochs in {time.time() - t0:.1f}s; "
      f"checkpoints in {args.run_dir}/store")
print("next: python examples/hindsight_replay.py --run-dir", args.run_dir)
