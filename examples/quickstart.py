"""Quickstart: train a model with Flor record on — the end-to-end driver.

    PYTHONPATH=src python examples/quickstart.py [--full]

Trains the florbench-100m model (reduced config by default so it runs on a
laptop CPU in ~2 minutes; --full trains the real 124M config) for a few
hundred steps with always-on hindsight-logging record. Afterwards, see
examples/hindsight_replay.py to query execution data you never logged.
"""
import argparse
import time

import jax

import repro.configs as C
import repro.flor as flor
from repro.data import PrefetchLoader, synthetic_batch
from repro.train.step import build_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true", help="real 124M config")
ap.add_argument("--epochs", type=int, default=8)
ap.add_argument("--steps-per-epoch", type=int, default=25)
ap.add_argument("--run-dir", default="/tmp/flor_quickstart")
args = ap.parse_args()

cfg = C.get("florbench-100m") if args.full else C.get_smoke("florbench-100m")
batch_size, seq = (8, 512) if args.full else (4, 128)

init_state, train_step = build_train_step(cfg, peak_lr=1e-3, warmup=20)
ts = jax.jit(train_step)
state = jax.jit(init_state)(jax.random.PRNGKey(0))

flor.init(args.run_dir, mode="record")        # <- the only Flor line you need
t0 = time.time()
for epoch in flor.generator(range(args.epochs)):
    if flor.skipblock.step_into("train"):
        loader = PrefetchLoader(
            lambda s: synthetic_batch(cfg, batch_size, seq, s),
            start_step=epoch * args.steps_per_epoch,
            num_steps=args.steps_per_epoch)
        for step, batch in loader:
            state, metrics = ts(state, batch)
        flor.log("loss", metrics["loss"])
    state = flor.skipblock.end("train", state)
    print(f"epoch {epoch}: loss={float(metrics['loss']):.4f} "
          f"({time.time() - t0:.1f}s)", flush=True)
flor.finish()
print(f"\nrecorded {args.epochs} epochs in {time.time() - t0:.1f}s; "
      f"checkpoints in {args.run_dir}/store")
print("next: python examples/hindsight_replay.py --run-dir", args.run_dir)
